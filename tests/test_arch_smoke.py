"""Per-arch smoke tests: reduced config, one forward + one train step on
CPU, asserting output shapes and no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models.transformer import apply_model, decode_step, init_cache, init_params
from repro.train import AdamWConfig, TrainConfig, make_train_step
from repro.train.optimizer import init_state

# ~2 min of model compiles on CPU: out of the default tier-1 run
pytestmark = pytest.mark.slow


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_and_train_step(arch):
    spec = get_arch(arch)
    cfg = spec.reduced
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    b, s = 2, 16
    inputs = (
        jax.random.randint(key, (b, s), 0, cfg.vocab)
        if spec.modality == "text"
        else jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
    )
    logits = apply_model(params, cfg, inputs)
    assert logits.shape == (b, s, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())

    tcfg = TrainConfig(adamw=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10))
    opt = init_state(tcfg.adamw, params)
    labels = jax.random.randint(key, (b, s), 0, cfg.vocab)
    step = make_train_step(cfg, tcfg)
    params2, opt2, metrics = step(params, opt, {"inputs": inputs, "labels": labels})
    assert np.isfinite(float(metrics["loss"]))
    assert int(opt2["step"]) == 1
    # params actually changed
    d0 = jax.tree.leaves(params)[0]
    d1 = jax.tree.leaves(params2)[0]
    assert not np.allclose(np.asarray(d0), np.asarray(d1))


@pytest.mark.parametrize(
    "arch", ["qwen2-1.5b", "mixtral-8x22b", "jamba-1.5-large-398b", "rwkv6-1.6b"]
)
def test_decode_matches_prefill(arch):
    spec = get_arch(arch)
    import dataclasses

    cfg = spec.reduced
    if cfg.moe is not None:
        # capacity-based MoE drops tokens shape-dependently; give the tiny
        # test configs enough capacity that prefill and decode agree exactly
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    cfg = dataclasses.replace(cfg, remat=False)
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    b, s = 2, 8
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    full = apply_model(params, cfg, toks)
    cache = init_cache(cfg, b, s)
    outs = []
    for t in range(s):
        lg, cache = decode_step(params, cfg, toks[:, t : t + 1], cache, jnp.int32(t))
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec), rtol=5e-4, atol=5e-4)


def test_all_archs_registered():
    assert len(ARCHS) == 10
    for a in ARCHS:
        spec = get_arch(a)
        assert spec.model.num_layers >= spec.reduced.num_layers
        for shape in ("train_4k", "prefill_32k", "decode_32k"):
            assert spec.shape_supported(shape)
    assert get_arch("rwkv6-1.6b").shape_supported("long_500k")
    assert not get_arch("gemma-2b").shape_supported("long_500k")
