"""Multi-tenant join serving: template canonicalization, batched
dispatch, admission control, and the redesigned ExecOptions surface."""
import warnings

import numpy as np
import pytest

from repro.core import ExecOptions, compiled_free_join, free_join, to_sorted_tuples
from repro.core.relcache import KeyedCache
from repro.relational.schema import Atom, Query, triangle_query
from repro.serve import (
    AdmissionController,
    AdmissionError,
    DecodeServeEngine,
    JoinServeEngine,
    QueryQuota,
    ServeEngine,
    canonicalize,
)
from tests.conftest import rand_rel


def _triangle(rng, n=300, dom=6):
    q = triangle_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, n, dom) for a in q.atoms}
    return q, rels


def _respell(q, rels, tag, order=None):
    """The same query as tenant `tag` would write it: its own alias names,
    its own atom order, over the same base relations."""
    atoms = [Atom(a.name, a.vars, f"{tag}_{a.alias}") for a in q.atoms]
    if order is not None:
        atoms = [atoms[i] for i in order]
    qi = Query(atoms)
    ri = {f"{tag}_{a.alias}": rels[a.alias] for a in q.atoms}
    return qi, ri


def _cached_runners(kc: KeyedCache):
    return [v[0] for v in kc._data.values()]


# ---- template canonicalization -----------------------------------------


def test_alpha_equivalent_spellings_share_one_template(rng):
    q, rels = _triangle(rng)
    t0, c0 = canonicalize(q, rels, {"x": 3})
    # different aliases, different constants
    q1, r1 = _respell(q, rels, "tenantA")
    t1, c1 = canonicalize(q1, r1, {"x": 5})
    # different atom order too (head order shifts with first appearance)
    q2, r2 = _respell(q, rels, "tenantB", order=[2, 0, 1])
    t2, c2 = canonicalize(q2, r2, {"x": 4})
    assert t0.key == t1.key == t2.key
    assert [int(c) for c in (c0[0], c1[0], c2[0])] == [3, 5, 4]
    # explicit head spellings of the same projection collapse as well
    t3, _ = canonicalize(Query(list(q.atoms), head=("z", "y", "x")), rels, {"x": 3})
    assert t3.key == t0.key


def test_real_differences_split_templates(rng):
    q, rels = _triangle(rng)
    base, _ = canonicalize(q, rels, {"x": 3})
    # different head SET
    proj, _ = canonicalize(Query(list(q.atoms), head=("x", "y")), rels, {"x": 3})
    assert proj.key != base.key
    # different aggregate
    cnt, _ = canonicalize(q, rels, {"x": 3}, agg="count")
    full, _ = canonicalize(q, rels, {"x": 3}, agg=None)
    assert cnt.key != full.key
    # different ExecOptions
    opt, _ = canonicalize(q, rels, {"x": 3}, options=ExecOptions(budget=64))
    assert opt.key != base.key
    # different filtered-variable set (same constant count)
    fy, _ = canonicalize(q, rels, {"y": 3})
    assert fy.key != base.key
    # same spelling over different base relations
    rng2 = np.random.default_rng(7)
    _, rels2 = _triangle(rng2)
    other, _ = canonicalize(q, rels2, {"x": 3})
    assert other.key != base.key


def test_filter_var_must_exist(rng):
    q, rels = _triangle(rng)
    with pytest.raises(ValueError, match="filter vars"):
        canonicalize(q, rels, {"nope": 1})


# ---- one compile across N ----------------------------------------------


def test_two_spellings_one_compiled_runner(rng):
    """The acceptance bar: alpha-equivalent queries with different
    constants compile exactly one probe runner, visible in the cache
    hit/miss counters and the runner's own compile count."""
    q, rels = _triangle(rng)
    kc = KeyedCache()
    eng = JoinServeEngine(slots=1, cache=kc)  # slots=1: each request is
    # its own dispatch, so a shared runner can only come from the cache
    qa, ra = _respell(q, rels, "a")
    qb, rb = _respell(q, rels, "b", order=[1, 2, 0])
    r0 = eng.submit(qa, ra, {"x": 2}, tenant="a")
    r1 = eng.submit(qb, rb, {"x": 4}, tenant="b")
    eng.step()  # serves r0: one cache miss, cold compile (+ any growth)
    assert kc.misses == 1 and kc.hits == 0
    (runner,) = _cached_runners(kc)
    cold_compiles = runner.compiles
    eng.step()  # serves r1: pure cache hit, zero new compiles
    assert kc.misses == 1 and kc.hits == 1
    assert runner.compiles == cold_compiles
    for req, c in ((r0, 2), (r1, 4)):
        assert req.done and req.error is None
        assert req.result == free_join(q, rels, agg="count", filters={"x": c})


# ---- batched dispatch ---------------------------------------------------


def test_batched_counts_match_eager(rng):
    q, rels = _triangle(rng)
    consts = [0, 1, 2, 3, 4, 5, 0, 3]
    eng = JoinServeEngine(slots=4)
    reqs = [
        eng.submit(*_respell(q, rels, f"t{i}"), {"x": c}, tenant=f"t{i}")
        for i, c in enumerate(consts)
    ]
    eng.run()
    assert eng.dispatches == 2  # 8 co-template requests at width 4
    for req, c in zip(reqs, consts):
        assert req.error is None
        assert req.result == free_join(q, rels, agg="count", filters={"x": c})


def test_batched_full_results_match_eager(rng):
    q, rels = _triangle(rng, n=150, dom=5)
    eng = JoinServeEngine(slots=4)
    consts = [0, 1, 2]
    reqs = [
        eng.submit(*_respell(q, rels, f"t{i}"), {"x": c}, tenant=f"t{i}", agg=None)
        for i, c in enumerate(consts)
    ]
    eng.run()
    for req, c in zip(reqs, consts):
        assert req.error is None
        got = to_sorted_tuples(req.result, q.head)
        want = to_sorted_tuples(free_join(q, rels, filters={"x": c}), q.head)
        assert got == want


def test_filterless_group_shares_one_call(rng):
    q, rels = _triangle(rng)
    eng = JoinServeEngine(slots=4)
    reqs = [eng.submit(*_respell(q, rels, f"t{i}"), tenant=f"t{i}") for i in range(4)]
    eng.run()
    assert eng.dispatches == 1
    want = free_join(q, rels, agg="count")
    assert [r.result for r in reqs] == [want] * 4


def test_distinct_templates_are_separate_groups(rng):
    q, rels = _triangle(rng)
    eng = JoinServeEngine(slots=8)
    ra = eng.submit(*_respell(q, rels, "a"), {"x": 1})
    rb = eng.submit(*_respell(q, rels, "b"), {"y": 1})  # different filter set
    retired = eng.step()
    assert retired == [ra] and not rb.done
    eng.run()
    assert rb.result == free_join(q, rels, agg="count", filters={"y": 1})


# ---- admission control --------------------------------------------------


def test_plan_cells_rejection_spares_cobatched(rng):
    """A quota-violating tenant is rejected pre-compile; co-batched
    tenants are served by the same single compile."""
    q, rels = _triangle(rng)
    adm = AdmissionController(per_tenant={"small": QueryQuota(max_plan_cells=1)})
    kc = KeyedCache()
    eng = JoinServeEngine(slots=4, admission=adm, cache=kc)
    ra = eng.submit(*_respell(q, rels, "a"), {"x": 1}, tenant="a")
    rs = eng.submit(*_respell(q, rels, "s"), {"x": 2}, tenant="small")
    rb = eng.submit(*_respell(q, rels, "b"), {"x": 3}, tenant="b")
    eng.run()
    assert isinstance(rs.error, AdmissionError) and rs.error.reason == "plan_cells"
    assert rs.result is None and rs.done
    for req, c in ((ra, 1), (rb, 3)):
        assert req.error is None
        assert req.result == free_join(q, rels, agg="count", filters={"x": c})
    assert adm.rejected == 1 and adm.admitted == 2
    (runner,) = _cached_runners(kc)
    compiles0, dispatches0 = runner.compiles, eng.dispatches
    # a repeat offender is rejected with zero XLA work and zero dispatches
    rs2 = eng.submit(*_respell(q, rels, "s2"), {"x": 4}, tenant="small")
    eng.run()
    assert isinstance(rs2.error, AdmissionError) and rs2.error.reason == "plan_cells"
    assert runner.compiles == compiles0 and eng.dispatches == dispatches0


def test_admission_counters_and_quota_resolution():
    adm = AdmissionController(
        default=QueryQuota(max_plan_cells=100),
        per_tenant={"vip": QueryQuota()},
    )
    adm.check_plan("vip", 10**9)  # vip: unbounded
    with pytest.raises(AdmissionError) as ei:
        adm.check_plan("anon", 101)
    assert ei.value.tenant == "anon" and ei.value.reason == "plan_cells"
    adm.check_plan("anon", 100)
    assert adm.admitted == 2 and adm.rejected == 1


# ---- fairness: round-robin over templates --------------------------------


def test_round_robin_prevents_template_starvation(rng):
    """A tenant streaming requests on one template must not starve another
    template queued behind it: the rotation guarantees template B is served
    by the second step even though six of A's requests arrived first (and
    keep arriving)."""
    q, rels = _triangle(rng)
    eng = JoinServeEngine(slots=2)
    qa, ra = _respell(q, rels, "a")
    qb, rb = _respell(q, rels, "b")
    a_reqs = [eng.submit(qa, ra, {"x": i}, tenant="a") for i in range(6)]
    r_b = eng.submit(qb, rb, {"y": 1}, tenant="b")
    eng.step()  # rotation position 0: template A (first arrival)
    assert not r_b.done and sum(r.done for r in a_reqs) == 2
    eng.submit(qa, ra, {"x": 6}, tenant="a")  # A keeps streaming
    eng.step()  # rotation position 1: template B, despite A's backlog
    assert r_b.done
    assert r_b.result == free_join(q, rels, agg="count", filters={"y": 1})
    eng.run()
    assert all(r.done for r in a_reqs)


# ---- measured-cost admission ---------------------------------------------


def test_measured_cost_admission(rng):
    """max_dispatch_us admits a template's first-ever dispatch (no EMA yet),
    then rejects the tenant once the measured EMA exceeds the quota —
    pre-dispatch, sparing co-batched tenants, with zero new XLA work."""
    q, rels = _triangle(rng)
    adm = AdmissionController(
        per_tenant={"cheap": QueryQuota(max_dispatch_us=0.001)}
    )
    kc = KeyedCache()
    eng = JoinServeEngine(slots=4, admission=adm, cache=kc)
    qa, ra = _respell(q, rels, "a")
    r0 = eng.submit(qa, ra, {"x": 1}, tenant="cheap")
    eng.run()
    # first dispatch: no measurement exists, so the impossible quota passes
    assert r0.error is None
    assert r0.result == free_join(q, rels, agg="count", filters={"x": 1})
    (t_key,) = eng.cost_ema_us  # ...and the dispatch recorded an EMA
    assert eng.cost_ema_us[t_key] > 0
    (runner,) = _cached_runners(kc)
    compiles0, dispatches0 = runner.compiles, eng.dispatches
    admitted0 = adm.admitted
    # warm template: the EMA now trips the quota before any dispatch, and a
    # co-batched unbounded tenant is still served
    r1 = eng.submit(qa, ra, {"x": 2}, tenant="cheap")
    r2 = eng.submit(qa, ra, {"x": 3}, tenant="vip")
    eng.run()
    assert isinstance(r1.error, AdmissionError) and r1.error.reason == "measured_cost"
    assert runner.compiles == compiles0
    assert r2.result == free_join(q, rels, agg="count", filters={"x": 3})
    assert eng.dispatches == dispatches0 + 1
    # a cost rejection is counted as rejected, never as admitted
    assert adm.rejected == 1 and adm.admitted == admitted0 + 1


def test_check_cost_unit():
    adm = AdmissionController(per_tenant={"t": QueryQuota(max_dispatch_us=50.0)})
    adm.check_cost("t", None)  # no measurement: passes, counts nothing
    adm.check_cost("t", 50.0)  # at the bound: passes
    with pytest.raises(AdmissionError) as ei:
        adm.check_cost("t", 50.1)
    assert ei.value.tenant == "t" and ei.value.reason == "measured_cost"
    assert adm.admitted == 0 and adm.rejected == 1


# ---- the redesigned options surface ------------------------------------


def test_legacy_kwargs_warn_and_match_options(rng):
    q, rels = _triangle(rng)
    with pytest.warns(DeprecationWarning, match="budget"):
        c_legacy = compiled_free_join(q, rels, budget=16)
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # the options path must be silent
        c_opts = compiled_free_join(q, rels, options=ExecOptions(budget=16))
    assert c_legacy == c_opts == free_join(q, rels, agg="count")


def test_free_join_compiled_rejects_eager_knobs(rng):
    q, rels = _triangle(rng)
    with pytest.raises(ValueError, match="mode"):
        free_join(q, rels, mode="simple", agg="count", compiled=True)
    with pytest.raises(ValueError, match="dynamic_cover"):
        free_join(q, rels, dynamic_cover=False, agg="count", compiled=True)
    # and the eager path rejects the compiled-only options
    with pytest.raises(ValueError, match="compiled path"):
        free_join(q, rels, agg="count", options=ExecOptions())
    # valid compiled delegation still works
    assert free_join(q, rels, agg="count", compiled=True) == free_join(
        q, rels, agg="count"
    )


def test_decode_engine_rename_keeps_alias():
    assert ServeEngine is DecodeServeEngine
