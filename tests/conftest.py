import numpy as np
import pytest

from repro.relational.relation import Relation


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def rand_rel(rng, name, vars_, n, dom):
    return Relation(name, {v: rng.integers(0, dom, n) for v in vars_})
