import numpy as np
import pytest

from repro.relational.relation import Relation


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: heavyweight model/train/serve tests, deselected by default "
        '(run them with -m slow, or everything with -m "slow or not slow")',
    )
    config.addinivalue_line(
        "markers",
        "chaos: fault-injection resilience tests (selected by default; CI "
        "also runs them standalone with -m chaos)",
    )


def pytest_collection_modifyitems(config, items):
    # No pytest.ini in this repo: default to -m "not slow" here so the
    # tier-1 suite stays fast. Any explicit -m on the command line wins.
    if config.option.markexpr:
        return
    selected, deselected = [], []
    for item in items:
        (deselected if "slow" in item.keywords else selected).append(item)
    if deselected:
        config.hook.pytest_deselected(items=deselected)
        items[:] = selected


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def rand_rel(rng, name, vars_, n, dom):
    return Relation(name, {v: rng.integers(0, dom, n) for v in vars_})
