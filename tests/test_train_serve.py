"""Training substrate + serving: optimizer math, checkpoint/resume,
compression error feedback, data determinism, straggler policy, serve
engine, paged KV."""
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.transformer import ModelConfig, init_params
from repro.serve import DecodeServeEngine, PagedAllocator, Request
from repro.train import AdamWConfig, TrainConfig, checkpoint, make_train_step
from repro.train.data import DataConfig, markov_batch, select_corpus_samples, synthetic_batch
from repro.train.optimizer import apply_updates, init_state, schedule
from repro.train.straggler import StragglerMonitor, StragglerPolicy, reshard_plan
from repro.train.trainer import init_train_state, xent_loss
from repro.relational.relation import Relation

CFG = ModelConfig(name="t", num_layers=2, d_model=32, num_heads=4, num_kv_heads=2,
                  d_ff=64, vocab=64, compute_dtype="float32", remat=False)


def test_adamw_matches_reference_step():
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                      clip_norm=1e9, warmup_steps=0, total_steps=10, min_lr_frac=1.0)
    params = {"w": jnp.array([1.0, -2.0])}
    grads = {"w": jnp.array([0.5, 0.5])}
    state = init_state(cfg, params)
    new_p, state, _ = apply_updates(cfg, params, grads, state)
    m = 0.1 * 0.5 / (1 - 0.9)
    v = 0.01 * 0.25 / (1 - 0.99)
    want = 1.0 - 0.1 * m / (np.sqrt(v) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"])[0], want, rtol=1e-5)


def test_schedule_warmup_and_cosine():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_frac=0.1)
    assert float(schedule(cfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(schedule(cfg, jnp.int32(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(schedule(cfg, jnp.int32(110))) == pytest.approx(0.1, rel=1e-3)


def test_grad_clipping_caps_norm():
    from repro.train.optimizer import clip_by_global_norm

    g = {"a": jnp.full((4,), 10.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


def test_xent_loss_masking():
    logits = jnp.zeros((1, 3, 5))
    labels = jnp.array([[1, -100, 2]])
    loss = xent_loss(logits, labels)
    assert float(loss) == pytest.approx(np.log(5), rel=1e-5)


@pytest.mark.slow
def test_train_loss_decreases_markov():
    tcfg = TrainConfig(adamw=AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60))
    params, opt = init_train_state(jax.random.PRNGKey(0), CFG, tcfg)
    step = jax.jit(make_train_step(CFG, tcfg), donate_argnums=(0, 1))
    dcfg = DataConfig(vocab=CFG.vocab, seq_len=32, global_batch=8)
    losses = []
    for i in range(60):
        batch = jax.tree.map(jnp.asarray, markov_batch(dcfg, i))
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.2


@pytest.mark.slow
def test_microbatch_accumulation_matches_full_batch():
    adamw = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    params, opt = init_train_state(jax.random.PRNGKey(0), CFG, TrainConfig(adamw=adamw))
    batch = jax.tree.map(jnp.asarray, synthetic_batch(DataConfig(64, 16, 8), 0))
    p1, _, m1 = make_train_step(CFG, TrainConfig(adamw=adamw, microbatches=1))(params, opt, batch)
    p2, _, m2 = make_train_step(CFG, TrainConfig(adamw=adamw, microbatches=4))(params, opt, batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)


def test_checkpoint_roundtrip_and_latest():
    params = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3), "b": jnp.ones(3, jnp.bfloat16)}
    with tempfile.TemporaryDirectory() as d:
        assert checkpoint.latest_step(d) is None
        checkpoint.save(d, 5, params)
        checkpoint.save(d, 10, params)
        assert checkpoint.latest_step(d) == 10
        like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        restored = checkpoint.restore(d, 10, like)
        for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(params)):
            np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_checkpoint_shape_mismatch_rejected():
    with tempfile.TemporaryDirectory() as d:
        checkpoint.save(d, 1, {"w": jnp.zeros((2, 2))})
        with pytest.raises(ValueError):
            checkpoint.restore(d, 1, {"w": jax.ShapeDtypeStruct((3, 3), jnp.float32)})


def test_data_stream_deterministic_and_elastic():
    dcfg = DataConfig(vocab=100, seq_len=8, global_batch=8)
    a = synthetic_batch(dcfg, 3, host=0, num_hosts=2)
    b = synthetic_batch(dcfg, 3, host=0, num_hosts=2)
    np.testing.assert_array_equal(a["inputs"], b["inputs"])
    c = synthetic_batch(dcfg, 3, host=1, num_hosts=2)
    assert not np.array_equal(a["inputs"], c["inputs"])
    plan = reshard_plan(4, 8, 256)
    assert plan["per_host_batch"] == 32


COMPRESSION_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.train.compression import compressed_psum, init_error
try:
    shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map
mesh = jax.make_mesh((4,), ("data",))
g = {"w": jnp.arange(32, dtype=jnp.float32).reshape(4, 8) / 7.3}
def f(gl, e):
    out, e2 = compressed_psum(gl, e, "data")
    return out, e2
fn = jax.jit(shard_map(f, mesh=mesh,
    in_specs=(jax.sharding.PartitionSpec("data"), jax.sharding.PartitionSpec("data")),
    out_specs=(jax.sharding.PartitionSpec("data"), jax.sharding.PartitionSpec("data"))))
err = {"w": jnp.zeros((4, 8), jnp.float32)}
out, err2 = fn(g, err)
# mean over 4 shards of per-shard rows, approx: compare with exact psum/4
exact = np.stack([np.asarray(g["w"])[i::1] for i in range(1)]).mean(0)
# each shard holds 1 row; psum/4 = mean of the 4 rows broadcast back
want = np.tile(np.asarray(g["w"]).reshape(4, 8).mean(0), (4, 1))
got = np.asarray(out["w"])
assert np.abs(got - want).max() < 0.02, (got[0], want[0])
# error feedback: residual equals x - dequant
assert np.isfinite(np.asarray(err2["w"])).all()
print("COMP_OK")
"""


@pytest.mark.slow
def test_compressed_psum_subprocess():
    env = {
        **os.environ,
        "XLA_FLAGS": "--xla_force_host_platform_device_count=4",
        "PYTHONPATH": "src",
    }
    res = subprocess.run([sys.executable, "-c", COMPRESSION_SCRIPT], capture_output=True,
                         text=True, env=env, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert "COMP_OK" in res.stdout, res.stderr[-2000:]


def test_compression_error_feedback_converges():
    # repeated compression of a constant gradient: mean of dequantized
    # values over steps converges to the true value (error feedback)
    from repro.train.compression import _quantize

    x = np.float32(0.013)
    scale = np.float32(1.0 / 127.0)
    err = np.float32(0.0)
    outs = []
    for _ in range(50):
        q = float(_quantize(jnp.float32(x + err), jnp.float32(scale)))
        deq = q * scale
        err = x + err - deq
        outs.append(deq)
    assert abs(np.mean(outs) - x) < 1e-4


def test_straggler_monitor_evicts_persistent_offender():
    mon = StragglerMonitor(4, StragglerPolicy(slow_factor=1.5, min_flags=3, restart_cost_steps=10))
    evicted = []
    for _ in range(5):
        r = mon.observe(np.array([1.0, 1.0, 1.0, 3.0]))
        evicted += r["evict"]
    assert 3 in evicted
    r = mon.observe(np.array([1.0, 1.0, 1.0, 1.0]))
    assert r["slow"] == []


def test_serve_engine_completes_all_requests():
    params = init_params(jax.random.PRNGKey(0), CFG)
    eng = DecodeServeEngine(params, CFG, slots=3, max_len=32)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, 64, 3).astype(np.int32), max_new=4) for i in range(5)
    ]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    assert all(len(r.out) >= 4 for r in reqs)


def test_paged_allocator_lookup_and_release():
    pa = PagedAllocator(num_pages=16, page_size=8)
    pa.alloc(1, 20)  # 3 pages
    pa.alloc(2, 8)  # 1 page
    slots = pa.lookup(np.array([1, 1, 1, 2, 9]), np.array([0, 1, 2, 0, 0]))
    assert (slots[:4] >= 0).all() and slots[4] == -1
    assert len(set(slots[:4].tolist())) == 4
    pa.release(1)
    assert pa.lookup(np.array([1]), np.array([0]))[0] == -1
    with pytest.raises(MemoryError):
        pa.alloc(3, 16 * 8 + 1)


def test_corpus_selection_relational():
    n = 1000
    rng = np.random.default_rng(0)
    docs = Relation(
        "Docs", {"doc": np.arange(n), "shard": rng.integers(0, 4, n), "lang": rng.integers(0, 3, n)}
    )
    quality = Relation("Quality", {"doc": np.arange(n), "score": rng.integers(0, 100, n)})
    dedup = Relation("Dedup", {"doc": np.arange(n), "canonical": np.arange(n)})
    keep = select_corpus_samples(docs, quality, dedup, min_quality=50)
    scores = np.asarray(quality.columns["score"])
    want = np.flatnonzero(scores >= 50)
    np.testing.assert_array_equal(keep, want)
