"""Launch-layer units that don't need 512 devices: sharding rules,
collective parsers, roofline math, arch/shape eligibility."""
import pytest

from repro.launch.dryrun import collective_bytes, collective_bytes_scaled

HLO = """
HloModule test
%region_2.345 (arg: (s32[], bf16[8,128])) -> (s32[], bf16[8,128]) {
  %ag = bf16[8,128]{1,0} all-gather(%x), dimensions={0}
  ROOT %t = tuple(...)
}
ENTRY %main () -> f32[] {
  %w = (s32[], bf16[8,128]) while(%init), condition=%cond, body=%region_2.345
  %ar = f32[64]{0} all-reduce(%y)
  ROOT %r = f32[] constant(0)
}
"""


def test_collective_bytes_counts_results():
    got = collective_bytes(HLO)
    assert got["all-gather"] == 8 * 128 * 2
    assert got["all-reduce"] == 64 * 4


def test_collective_bytes_scaled_multiplies_while_bodies():
    got = collective_bytes_scaled(HLO, repeats=10)
    assert got["all-gather"] == 8 * 128 * 2 * 10  # inside the while body
    assert got["all-reduce"] == 64 * 4  # top level: counted once


def test_param_spec_rules():
    jax = pytest.importorskip("jax")
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    # build a fake 16x16 mesh object via mock shapes: use Mesh of 1 device
    # but validate the *rule logic* through a stub mesh-like object
    class StubMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    from repro.launch.sharding import param_spec

    # attention: heads divisible -> heads sharded
    spec = param_spec("['blocks'][0]['mixer']['wq']", (56, 6144, 48, 128), StubMesh())
    assert spec == jax.sharding.PartitionSpec(None, "data", "model", None)
    # heads NOT divisible -> replicate over model (never head_dim)
    spec = param_spec("['blocks'][0]['mixer']['wk']", (56, 6144, 8, 128), StubMesh())
    assert spec == jax.sharding.PartitionSpec(None, "data", None, None)
    # MoE: E divisible -> expert parallel
    spec = param_spec("['blocks'][0]['ffn']['wi']", (35, 128, 7168, 4864), StubMesh())
    assert spec[1] == "model"
    # MoE: E not divisible -> ffn-dim TP + FSDP on the other dim
    spec = param_spec("['blocks'][0]['ffn']['wi']", (56, 8, 6144, 16384), StubMesh())
    assert spec == jax.sharding.PartitionSpec(None, None, "data", "model")
    # embeddings vocab-parallel
    spec = param_spec("['embed']['table']", (151936, 1536), StubMesh())
    assert spec == jax.sharding.PartitionSpec("model", "data")
    # 1D norm scales: generic rule shards the (divisible) dim over model
    spec = param_spec("['final_norm']['scale']", (1536,), StubMesh())
    assert spec == jax.sharding.PartitionSpec("model")


def test_batch_and_cache_specs():
    import jax

    class StubMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    from repro.launch.sharding import batch_spec, cache_spec

    assert batch_spec((256, 4096), StubMesh()) == jax.sharding.PartitionSpec("data", None)
    # batch=1 long context: shard the sequence dim instead
    s = batch_spec((1, 524288), StubMesh())
    assert s == jax.sharding.PartitionSpec(None, "data")
    # cache (R, B, T, G, hd): batch over data, a divisible tail dim over model
    s = cache_spec((28, 128, 32768, 2, 128), StubMesh())
    assert s[1] == "data" and "model" in s


def test_model_flops_moe_active_params():
    from repro.launch.roofline import model_flops

    dense = model_flops("qwen2-1.5b", "train_4k")
    # 6 * N * D within 5%
    assert abs(dense / (6 * 1.54e9 * 256 * 4096) - 1) < 0.05
    moe_total = model_flops("mixtral-8x22b", "train_4k")
    # active ~39B of 140B params
    assert 6 * 30e9 * 1.05e6 < moe_total < 6 * 50e9 * 1.05e6


def test_long_context_eligibility_matches_design():
    from repro.configs import ARCHS, get_arch

    eligible = {a for a in ARCHS if get_arch(a).shape_supported("long_500k")}
    assert eligible == {"rwkv6-1.6b", "jamba-1.5-large-398b", "mixtral-8x22b"}
