"""The static verifier's two-sided contract, plus the jaxpr auditor's.

Side one (mutation fuzzing): take VALID planner output, inject one
defect of a known class, and the lint report must NAME that class by
rule id — nine distinct defect classes below, each with a deterministic
expected rule. Side two (zero false positives): every rule stays silent
on everything the real optimizer + capacity planner emit, across the
whole analysis corpus. A verifier missing either side is worse than no
verifier: silent on bugs, or crying wolf on good plans.

The jaxpr auditor gets the same treatment: hand-built programs that
exhibit each hazard (callback sync point, unrolled probe loop, baked
buffer const) must be flagged, and the corpus's real compiled executor
must come back clean. Finally, the explicit-transfer discipline the
auditor assumes is locked by a jax.transfer_guard("disallow") regression
test around the warm batched serving step.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (
    PlanVerificationError,
    Report,
    audit_jaxpr,
    audit_runner,
    lint_capacities,
    lint_chain,
    lint_plan,
    lint_query,
    lint_schedule,
    lint_stage_dag,
    lint_template,
    lint_tree,
)
from repro.analysis.corpus import build_runner, corpus_cases
from repro.core.api import ExecOptions
from repro.core.capacity import plan_chain_capacities
from repro.core.compiled import StaticSchedule, _static_schedule
from repro.core.optimizer import JoinOrderOptimizer, Stats
from repro.core.plan import FreeJoinPlan, Subatom, stage_plans
from repro.relational.schema import Atom, Query
from repro.serve.join_engine import JoinServeEngine
from repro.serve.templates import canonicalize

CASES = {c.name: c for c in corpus_cases()}


def _planned(case):
    """Fresh planner output for a corpus case, no compilation: the stage
    chain and its ChainCapacityPlan exactly as _acquire_runner derives
    them before the executor build."""
    stats = Stats(case.relations, cached=True)
    tree = JoinOrderOptimizer().choose(case.query, case.relations, stats=stats)
    stages = stage_plans(case.query, tree)
    chain = plan_chain_capacities(stages, stats=stats)
    return stages, chain


# ---------------------------------------------------------------------------
# Side two first: zero false positives on real planner output
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(CASES))
def test_corpus_plans_lint_clean(name):
    case = CASES[name]
    stages, chain = _planned(case)
    rep = lint_chain(
        stages, chain, filter_vars=case.filter_vars, batch=case.batch
    )
    assert not rep.diagnostics, f"false positive(s) on {name}:\n{rep}"


@pytest.mark.parametrize("name", ["star-filtered", "star-batched"])
def test_corpus_templates_idempotent(name):
    case = CASES[name]
    template, _ = canonicalize(
        case.query, case.relations, case.filters, options=case.options
    )
    rep = lint_template(template)
    assert rep.ok, str(rep)


# ---------------------------------------------------------------------------
# Side one: mutation fuzzing — every injected defect class is NAMED
# ---------------------------------------------------------------------------


def test_mutation_unbound_probe_var():
    stages, _ = _planned(CASES["triangle"])
    plan = stages[-1][1]
    nodes = [list(n) for n in plan.nodes]
    # rename a probe subatom's var to one nothing ever binds
    for node in nodes:
        if len(node) > 1 and node[1].vars:
            node[1] = Subatom(node[1].alias, ("__never_bound",))
            break
    bad = FreeJoinPlan(plan.query, nodes)
    rules = lint_plan(bad).rules()
    assert "unbound-probe-var" in rules
    assert "plan-not-partitioning" in rules  # the rename also breaks Def 3.5


def test_mutation_missing_cover():
    case = CASES["triangle"]
    # node 1 introduces BOTH y and z, but its subatoms each carry only one
    # of them — no subatom contains all new vars, so no cover (Def 3.7)
    bad = FreeJoinPlan(
        case.query,
        [
            [Subatom("R", ("x",))],
            [Subatom("S", ("y",)), Subatom("T", ("z",))],
        ],
    )
    assert "node-missing-cover" in lint_plan(bad).rules()


def test_mutation_unbound_head_var():
    case = CASES["star"]
    q = Query(case.query.atoms, head=(*case.query.head, "__alien"))
    assert "unbound-head-var" in lint_query(q).rules()


def test_mutation_schedule_level_swap():
    stages, _ = _planned(CASES["triangle"])
    plan = stages[-1][1]
    sched = _static_schedule(plan)
    alias = next(a for a, lo in sched.level_ops.items() if len(lo.levels) >= 2)
    lo = sched.level_ops[alias]
    corrupted = StaticSchedule(
        entries=sched.entries,
        level_ops={
            **sched.level_ops,
            alias: dataclasses.replace(lo, levels=lo.levels[::-1]),
        },
    )
    assert "schedule-level-mismatch" in lint_schedule(plan, corrupted).rules()


def test_mutation_capacity_zero():
    stages, chain = _planned(CASES["star"])
    _name, plan = stages[-1]
    cp = chain.stages[-1]
    bad = dataclasses.replace(cp, capacities=(0,) + cp.capacities[1:])
    assert "capacity-not-positive" in lint_capacities(plan, bad).rules()


def test_mutation_capacity_over_agm():
    stages, chain = _planned(CASES["star"])
    _name, plan = stages[-1]
    cp = chain.stages[-1]
    assert cp.agm, "planner must record AGM bounds for this check to bite"
    bad = dataclasses.replace(cp, capacities=(10**9,) + cp.capacities[1:])
    assert "capacity-over-agm" in lint_capacities(plan, bad).rules()


def test_mutation_compact_target_oversize():
    stages, chain = _planned(CASES["star"])
    _name, plan = stages[-1]
    cp = chain.stages[-1]
    ct = list(cp.compact_to)
    ct[0] = cp.capacities[0]  # "compacting" into a buffer the same size
    bad = dataclasses.replace(cp, compact_to=tuple(ct))
    assert "compact-target-oversize" in lint_capacities(plan, bad).rules()


def test_mutation_stage_order_break():
    stages, _ = _planned(CASES["bushy"])
    assert len(stages) >= 2, "bushy corpus case must decompose into stages"
    reordered = [stages[-1], *stages[:-1]]  # root first: reads stages not yet defined
    rules = lint_stage_dag(reordered).rules()
    assert "stage-dag-order" in rules
    assert "stage-root-last" in rules


def test_mutation_stage_schema_mismatch():
    stages, _ = _planned(CASES["bushy"])
    name, root = stages[-1]
    stage_names = {n for n, _ in stages}
    atoms = []
    broke = False
    for a in root.query.atoms:
        if not broke and a.alias in stage_names:
            atoms.append(Atom(a.name, a.vars[:-1], a.alias))  # drop a column
            broke = True
        else:
            atoms.append(a)
    assert broke, "bushy root stage must reference an earlier stage"
    bad_root = FreeJoinPlan(Query(atoms), root.nodes)
    mutated = [*stages[:-1], (name, bad_root)]
    assert "stage-schema-mismatch" in lint_stage_dag(mutated).rules()


def test_mutation_filter_unbound():
    stages, chain = _planned(CASES["star"])
    rep = lint_chain(stages, chain, filter_vars=("__nope",))
    assert "filter-unbound" in rep.rules()


def test_mutation_plan_tree_atoms():
    case = CASES["triangle"]
    # a tree over only two of the three atoms
    a, b, _c = case.query.atoms
    from repro.core.plan import BinaryPlan

    rep, stages = lint_tree(case.query, BinaryPlan(a, b))
    assert stages is None
    assert "plan-tree-atoms" in rep.rules()


def test_defect_class_coverage():
    """The ISSUE floor: >= 5 distinct defect classes detectable by rule."""
    detectable = {
        "unbound-probe-var",
        "plan-not-partitioning",
        "node-missing-cover",
        "unbound-head-var",
        "schedule-level-mismatch",
        "capacity-not-positive",
        "capacity-over-agm",
        "compact-target-oversize",
        "stage-dag-order",
        "stage-schema-mismatch",
        "filter-unbound",
        "plan-tree-atoms",
    }
    assert len(detectable) >= 5


# ---------------------------------------------------------------------------
# jaxpr audit: hazards flagged, real executors clean
# ---------------------------------------------------------------------------


def test_audit_flags_callback():
    def f(x):
        jax.debug.callback(lambda v: None, x)
        return x * 2

    jaxpr = jax.make_jaxpr(f)(jnp.arange(4))
    rep = audit_jaxpr(jaxpr, expect_loop=False)
    assert "host-callback" in rep.rules()


def test_audit_flags_unrolled_loop():
    def f(x):
        idx = jnp.argsort(x)
        for _ in range(40):  # a python loop traced into 40 gathers
            x = x[idx]
        return x

    jaxpr = jax.make_jaxpr(f)(jnp.arange(8))
    rep = audit_jaxpr(jaxpr, expect_loop=True)
    assert "probe-loop-unrolled" in rep.rules()
    assert "probe-loop-missing" in rep.rules()  # and no while/scan anywhere


def test_audit_flags_baked_buffer():
    big = jnp.arange(100_000)

    def f(i):
        return big[i]

    jaxpr = jax.make_jaxpr(f)(jnp.int32(3))
    rep = audit_jaxpr(jaxpr, expect_loop=False)
    assert "captured-buffer-const" in rep.rules()


def test_audit_accepts_rolled_loop():
    def f(x):
        return jax.lax.fori_loop(0, 40, lambda i, v: v[jnp.argsort(v)], x)

    jaxpr = jax.make_jaxpr(f)(jnp.arange(8))
    rep = audit_jaxpr(jaxpr, expect_loop=True)
    assert rep.ok, str(rep)


def test_audit_clean_on_compiled_star_runner():
    """The acceptance bar's audit half, in-tree: the production executor
    for the star corpus case (the bench star shape) audits clean."""
    case = CASES["star"]
    runner, rels = build_runner(case)
    runner.run_relations(rels)
    rep = audit_runner(runner, rels, name="star")
    assert rep.ok, str(rep)


# ---------------------------------------------------------------------------
# wiring: ExecOptions.verify, optimizer debug_lint, submit-time rejection
# ---------------------------------------------------------------------------


def test_exec_options_verify_passes_on_valid_query():
    case = CASES["triangle"]
    from repro.core.api import compiled_free_join

    n_plain = compiled_free_join(case.query, case.relations)
    n_verified = compiled_free_join(
        case.query, case.relations, options=ExecOptions(verify=True)
    )
    assert n_plain == n_verified


def test_optimizer_debug_lint_passes_on_corpus():
    case = CASES["bushy"]
    opt = JoinOrderOptimizer(debug_lint=True)
    tree = opt.choose(case.query, case.relations)
    assert tree is not None


def test_submit_rejects_invalid_head_without_crash():
    """Admission-time verification: a query whose head names a variable no
    atom binds is REJECTED (handle errored, counter bumped, nothing
    enqueued) — canonicalize would silently drop the head var, and the
    old behavior served a silently-wrong projection."""
    case = CASES["star"]
    bad_q = Query(case.query.atoms, head=(*case.query.head, "__alien"))
    eng = JoinServeEngine(slots=2)
    before = eng.admission.rejected
    req = eng.submit(bad_q, case.relations, {"y": 3}, tenant="t0")
    assert req.done and isinstance(req.error, PlanVerificationError)
    assert "unbound-head-var" in req.error.report.rules()
    assert eng.admission.rejected == before + 1
    assert not eng.queue  # never enqueued: co-batched tenants are spared
    # a good request on the same engine still serves normally
    ok = eng.submit(case.query, case.relations, {"y": 3}, tenant="t0")
    eng.run()
    assert ok.done and ok.error is None


def test_submit_rejects_unknown_filter_var():
    case = CASES["star"]
    eng = JoinServeEngine(slots=2)
    req = eng.submit(case.query, case.relations, {"__nope": 1})
    assert req.done and req.error is not None
    assert not eng.queue


# ---------------------------------------------------------------------------
# explicit-transfer discipline: the warm batched serving step performs
# ZERO implicit host transfers
# ---------------------------------------------------------------------------


def test_warm_batched_dispatch_zero_implicit_transfers():
    case = CASES["star"]
    eng = JoinServeEngine(slots=4)

    def submit_round(c0):
        return [
            eng.submit(case.query, case.relations, {"y": c0 + i}, tenant=f"t{i}")
            for i in range(4)
        ]

    warm = submit_round(0)
    eng.run()
    assert all(r.done and r.error is None for r in warm)
    # second round: same template, cached runner, cached tries, uploaded
    # columns — under transfer_guard("disallow") any *implicit* host
    # transfer raises; explicit device_put/device_get remain legal
    reqs = submit_round(10)
    with jax.transfer_guard("disallow"):
        eng.run()
    assert all(r.done and r.error is None for r in reqs)
    for r in reqs:
        assert isinstance(r.result, int)


def test_report_surface():
    rep = Report()
    assert rep.ok and not rep
    rep.warning("w-rule", "p", "m")
    assert rep.ok and rep  # warnings don't fail
    rep.error("e-rule", "p2", "m2")
    assert not rep.ok
    assert rep.rules() == {"w-rule", "e-rule"}
    with pytest.raises(PlanVerificationError) as ei:
        rep.raise_errors()
    assert ei.value.report is rep
    assert "e-rule" in str(ei.value)
