"""Fully-compiled bushy plans: every stage chained on device.

The contract under test (PR 4): compiled_free_join runs the *whole* stage
chain — non-root stages included — inside one AdaptiveExecutor call, with
zero eager-engine invocations; results match the eager engine exactly
(count and agg=None materialization), including zero-row stage outputs and
stages whose output overflows its planned capacity.
"""
from dataclasses import replace

import numpy as np
import pytest

from repro.core import compiled_free_join, free_join, optimize, to_sorted_tuples
from repro.core.api import _stage_plans
from repro.core.capacity import plan_chain_capacities
from repro.core.compiled import AdaptiveExecutor
from repro.core.engine import execute as eager_execute
from repro.core.optimizer import Stats
from repro.core.plan import BinaryPlan
from repro.relational.relation import Relation
from repro.relational.schema import Atom, Query
from tests.conftest import rand_rel


def two_stage_case(rng, n=40, dom=8):
    """((A ⋈ B) ⋈ (C ⋈ D)): one non-root stage + the root."""
    q = Query(
        [Atom("A", ("x", "y")), Atom("B", ("y", "z")), Atom("C", ("z", "w")), Atom("D", ("w", "u"))]
    )
    tree = BinaryPlan(BinaryPlan(q.atoms[0], q.atoms[1]), BinaryPlan(q.atoms[2], q.atoms[3]))
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, n, dom) for a in q.atoms}
    return q, tree, rels


def three_stage_case(rng, n=30, dom=12):
    """(((R0 R1)(R2 R3))(R4 R5)) path: two non-root stages + the root."""
    atoms = [Atom(f"R{i}", (f"v{i}", f"v{i + 1}")) for i in range(6)]
    q = Query(atoms)
    tree = BinaryPlan(
        BinaryPlan(BinaryPlan(atoms[0], atoms[1]), BinaryPlan(atoms[2], atoms[3])),
        BinaryPlan(atoms[4], atoms[5]),
    )
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, n, dom) for a in q.atoms}
    return q, tree, rels


def four_stage_case(rng, n=12, dom=8):
    """The Sec 5.4 hijacked-optimizer regime: a balanced bushy tree over an
    8-atom star (three non-root stages + the root)."""
    q = Query([Atom(f"S{i}", ("h", f"s{i}")) for i in range(8)])
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, n, dom) for a in q.atoms}
    tree = optimize(q, rels, bad=True)
    return q, tree, rels


CASES = [two_stage_case, three_stage_case, four_stage_case]


# ---- parity: eager vs fully-compiled on multi-stage plans -----------------


@pytest.mark.parametrize("case", CASES)
def test_bushy_count_parity(case, rng):
    q, tree, rels = case(rng)
    assert len(tree.decompose()) >= 2, "the plan must actually be bushy"
    want = free_join(q, rels, tree, agg="count")
    info = {}
    got = compiled_free_join(q, rels, tree, agg="count", info=info)
    assert got == want


@pytest.mark.parametrize("case", CASES)
def test_bushy_materialization_parity(case, rng):
    q, tree, rels = case(rng)
    want = free_join(q, rels, tree, agg=None)
    got = compiled_free_join(q, rels, tree, agg=None)
    assert to_sorted_tuples(got, q.head) == to_sorted_tuples(want, q.head)


def test_bushy_bag_multiplicity_across_stage(rng):
    """Duplicate rows inside a stage input must carry their multiplicity
    through the stage buffer into the root (weighted StaticTrie mult)."""
    q = Query(
        [Atom("A", ("x", "y")), Atom("B", ("y", "z")), Atom("C", ("z", "w")), Atom("D", ("w", "u"))]
    )
    tree = BinaryPlan(BinaryPlan(q.atoms[0], q.atoms[1]), BinaryPlan(q.atoms[2], q.atoms[3]))
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 30, 6) for a in q.atoms}
    # triplicate one C row: every join result through it counts three times
    c = rels["C"].columns
    rels["C"] = Relation("C", {k: np.concatenate([v, v[:1], v[:1]]) for k, v in c.items()})
    want = free_join(q, rels, tree, agg="count")
    assert compiled_free_join(q, rels, tree, agg="count") == want
    got = compiled_free_join(q, rels, tree, agg=None)
    assert to_sorted_tuples(got, q.head) == to_sorted_tuples(
        free_join(q, rels, tree, agg=None), q.head
    )


# ---- the CI acceptance assertion: one call chain, zero eager work ---------


def test_bushy_single_call_chain_zero_eager(rng, monkeypatch):
    """A 3-stage bushy plan issues exactly one AdaptiveExecutor call chain
    and never touches the eager engine."""
    q, tree, rels = three_stage_case(rng)
    assert len(tree.decompose()) == 3
    want = free_join(q, rels, tree, agg="count")

    eager_calls = [0]

    def counting_execute(*a, **k):
        eager_calls[0] += 1
        return eager_execute(*a, **k)

    import repro.core.api as api_mod

    monkeypatch.setattr(api_mod.engine, "execute", counting_execute)
    info = {}
    got = compiled_free_join(q, rels, tree, agg="count", info=info)
    assert got == want
    assert eager_calls[0] == 0, "the compiled path must never invoke the eager engine"
    assert info["runner"].calls == 1, "one call chain for the whole bushy plan"
    # the hybrid baseline, by contrast, runs the eager engine per non-root stage
    assert compiled_free_join(q, rels, tree, agg="count", chain_stages=False) == want
    assert eager_calls[0] == 2


# ---- zero-row stage output ------------------------------------------------


def test_bushy_zero_row_stage_output(rng):
    """A stage whose own join is empty (C and D share no w values) must
    flow an all-pad buffer through the chain: count 0, no output rows."""
    q, tree, rels = two_stage_case(rng)
    rels["C"] = Relation("C", {"z": np.arange(10), "w": np.arange(10)})
    rels["D"] = Relation("D", {"w": np.arange(100, 110), "u": np.arange(10)})
    assert free_join(q, rels, tree, agg="count") == 0
    assert compiled_free_join(q, rels, tree, agg="count") == 0
    got = compiled_free_join(q, rels, tree, agg=None)
    assert to_sorted_tuples(got, q.head) == []


def test_bushy_empty_input_relation_in_stage(rng):
    q, tree, rels = two_stage_case(rng)
    rels["D"] = Relation("D", {"w": np.zeros(0, np.int64), "u": np.zeros(0, np.int64)})
    assert compiled_free_join(q, rels, tree, agg="count") == 0
    got = compiled_free_join(q, rels, tree, agg=None)
    assert to_sorted_tuples(got, q.head) == []


# ---- a stage output overflowing its planned capacity ----------------------


def test_bushy_stage_overflow_forces_adaptive_retry(rng):
    """Undersize only stage 0's buffers: the chain must report that stage's
    needs, grow exactly the offending nodes, and converge to parity — the
    untouched stages keep their planned capacities."""
    q, tree, rels = three_stage_case(rng)
    want = free_join(q, rels, tree, agg="count")
    stages = _stage_plans(q, tree)
    chain = plan_chain_capacities(stages, stats=Stats(rels))
    s0 = chain.stages[0]
    tiny = replace(
        s0,
        capacities=(64,) * len(s0.capacities),
        compact_to=(None,) * len(s0.capacities),
    )
    undersized = replace(chain, stages=(tiny,) + chain.stages[1:])
    ex = AdaptiveExecutor(tuple(stages), undersized, agg="count")
    assert ex.run_relations(rels) == want
    assert ex.retries > 0, "a forced stage overflow must actually retry"
    assert max(ex.cap_plan.stages[0].capacities) > 64
    for k in range(1, len(chain.stages)):
        assert ex.cap_plan.stages[k].capacities == chain.stages[k].capacities
    # steady state: the grown chain is cached — a second call never re-runs
    retries, compiles = ex.retries, ex.compiles
    assert ex.run_relations(rels) == want
    assert ex.retries == retries and ex.compiles == compiles


def test_bushy_chain_plan_grow_to_identity_when_unchanged(rng):
    q, tree, rels = two_stage_case(rng)
    chain = plan_chain_capacities(_stage_plans(q, tree), stats=Stats(rels))
    # growing a disabled compaction target is a no-op and returns self
    assert chain.grow_to(0, 0, 10**6, compaction=True) is chain or (
        chain.stages[0].compact_to[0] is not None
    )
    grown = chain.grow_to(0, 0, 10**6)
    assert grown is not chain
    assert grown.stages[0].capacities[0] >= 10**6
    assert grown.stages[1:] == chain.stages[1:]


# ---- hybrid baseline stays available --------------------------------------


@pytest.mark.parametrize("case", CASES[:2])
def test_hybrid_baseline_matches_chain(case, rng):
    q, tree, rels = case(rng)
    want = free_join(q, rels, tree, agg="count")
    assert compiled_free_join(q, rels, tree, agg="count", chain_stages=False) == want
    got = compiled_free_join(q, rels, tree, agg=None, chain_stages=False)
    assert to_sorted_tuples(got, q.head) == to_sorted_tuples(
        free_join(q, rels, tree, agg=None), q.head
    )
