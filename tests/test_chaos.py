"""Chaos suite: deterministic fault injection against the serving stack.

The contract under test (the resilience acceptance bar): under injected
faults, JoinServeEngine.step() completes every admitted request —
possibly degraded, never crashed — per-tenant quotas hold (an eviction
storm is charged to its offender, co-batched compliant tenants never
pay), StandingQueryEngine recovers to match the eager oracle, and with a
budget set the memory governor's governed bytes never exceed it.

Every fault here is armed through core.faults.inject: no randomness, no
real device pressure, each test reproducible bit-for-bit. CI runs this
file standalone as the `chaos` job (-m chaos) and asserts the recovery
counters in the job summary via `python -m repro.core.faults`.
"""
import warnings

import numpy as np
import pytest

from repro.core import compiled_free_join, faults, free_join, membudget, relcache
from repro.core.membudget import MemoryBudgetError
from repro.relational.relation import Relation
from repro.relational.schema import triangle_query
from repro.serve import (
    AdmissionController,
    JoinServeEngine,
    QueryQuota,
    StandingQueryEngine,
)

pytestmark = pytest.mark.chaos


def _triangle(seed=0, n=400, dom=8):
    rng = np.random.default_rng(seed)
    q = triangle_query()
    rels = {
        a.alias: Relation(a.alias, {v: rng.integers(0, dom, n) for v in a.vars})
        for a in q.atoms
    }
    return q, rels


def _fast(eng):
    eng.backoff_base_ms = 0.0  # keep chaos rounds instant
    return eng


def _oracle(q, rels, c):
    return free_join(q, rels, agg="count", filters={"x": c})


# ---- the degradation ladder --------------------------------------------


def test_compile_fail_degrades_to_halved_batch(rng):
    q, rels = _triangle()
    eng = _fast(JoinServeEngine(slots=2))
    with faults.inject("compile_fail", times=1) as f:
        reqs = [eng.submit(q, rels, {"x": c}) for c in (2, 5)]
        eng.run()
    assert f.fired == 1
    assert eng.faults_absorbed == 1
    for req, c in zip(reqs, (2, 5)):
        assert req.done and req.error is None
        assert req.result == _oracle(q, rels, c)
        assert req.degraded_to == "halved"
    assert eng.degraded["halved"] == 2


def test_repeated_compile_fail_walks_every_rung_to_eager(rng):
    """Three consecutive compile failures exhaust full-width, halved, and
    unbatched compiles; the eager host rung still answers correctly."""
    q, rels = _triangle(seed=1)
    eng = _fast(JoinServeEngine(slots=2))
    with faults.inject("compile_fail", times=3) as f:
        reqs = [eng.submit(q, rels, {"x": c}) for c in (1, 4)]
        eng.run()
    assert f.fired == 3
    for req, c in zip(reqs, (1, 4)):
        assert req.done and req.error is None
        assert req.result == _oracle(q, rels, c)
        assert req.degraded_to == "eager"
    assert eng.degraded["eager"] == 2
    assert eng.faults_absorbed >= 3


def test_device_oom_at_dispatch_degrades_not_crashes(rng):
    q, rels = _triangle(seed=2)
    eng = _fast(JoinServeEngine(slots=2))
    with faults.inject("device_oom", times=1) as f:
        reqs = [eng.submit(q, rels, {"x": c}) for c in (3, 6)]
        eng.run()
    assert f.fired == 1
    for req, c in zip(reqs, (3, 6)):
        assert req.done and req.error is None
        assert req.result == _oracle(q, rels, c)
        assert req.degraded_to is not None


def test_governor_shed_feeds_the_ladder(rng):
    """A MemoryBudgetError raised by adaptive growth is recoverable: the
    ladder absorbs it like any device fault."""
    assert faults.recoverable(MemoryBudgetError(10, 0, 5))
    assert not faults.recoverable(ValueError("nope"))


def test_unrecoverable_errors_still_propagate(rng):
    """The ladder must not become an exception sponge: a plain bug in the
    dispatch path surfaces to the caller."""
    q, rels = _triangle(seed=3)
    eng = _fast(JoinServeEngine(slots=2))
    eng.submit(q, rels, {"x": 2})

    def boom(*a, **k):
        raise ValueError("genuine bug")

    eng._dispatch_batched = boom
    with pytest.raises(ValueError, match="genuine bug"):
        eng.step()


# ---- overflow storms: offender isolation -------------------------------


def test_eviction_storm_never_evicts_compliant_tenant(rng):
    """N consecutive over-quota lanes from one tenant: each eviction is
    charged to the offender, and the compliant co-batched tenant is
    served the correct answer with an untouched budget."""
    q, rels = _triangle(seed=4)
    adm = AdmissionController(default=QueryQuota(max_retries=5))
    eng = _fast(JoinServeEngine(slots=4, admission=adm))
    evil = [eng.submit(q, rels, {"x": c}, tenant="evil") for c in (0, 1, 2)]
    good = eng.submit(q, rels, {"x": 5}, tenant="good")
    # the storm names lane 0 three times; evil's requests occupy the head
    # lanes in submit order, so each firing evicts evil's next request
    with faults.inject("overflow_storm", times=3, lanes=(0, 0, 0)) as f:
        eng.run()
    assert f.fired == 3
    assert all(r.done and r.error is not None for r in evil)
    assert good.done and good.error is None
    assert good.result == _oracle(q, rels, 5)
    assert good.degraded_to is None  # served on the fast path, not a rung
    assert adm.rejected_by.get("evil") == 3
    assert "good" not in adm.rejected_by


def test_retry_budget_charged_to_offender_wholesale(rng):
    """Once a tenant's evictions exceed its OWN max_retries, its remaining
    queued requests are rejected wholesale (reason "retries") instead of
    burning more dispatch rounds; the compliant tenant still completes."""
    q, rels = _triangle(seed=5)
    adm = AdmissionController(
        default=QueryQuota(),
        per_tenant={"evil": QueryQuota(max_retries=1)},
    )
    eng = _fast(JoinServeEngine(slots=4, admission=adm))
    evil = [eng.submit(q, rels, {"x": c}, tenant="evil") for c in (0, 1, 2)]
    good = eng.submit(q, rels, {"x": 5}, tenant="good")
    with faults.inject("overflow_storm", times=2, lanes=(0, 0)) as f:
        eng.run()
    assert f.fired == 2
    # 2 lane evictions + 1 wholesale rejection, all charged to evil
    assert adm.rejected_by.get("evil") == 3
    assert adm.rejected_reasons.get("retries", 0) >= 1
    assert "good" not in adm.rejected_by
    wholesale = [r for r in evil if getattr(r.error, "reason", None) == "retries"]
    assert len(wholesale) == 1
    assert good.done and good.error is None
    assert good.result == _oracle(q, rels, 5)


# ---- deadlines + slow dispatch -----------------------------------------


def test_slow_dispatch_reaps_expired_deadline(rng):
    q, rels = _triangle(seed=6)
    eng = _fast(JoinServeEngine(slots=1))
    r1 = eng.submit(q, rels, {"x": 2})
    r2 = eng.submit(q, rels, {"x": 4}, deadline_ms=30.0)
    with faults.inject("slow_dispatch", times=1, delay_s=0.2) as f:
        eng.run()
    assert f.fired == 1
    assert r1.done and r1.error is None and r1.result == _oracle(q, rels, 2)
    # r2 waited behind the injected stall past its deadline: rejected,
    # never dispatched late
    assert r2.done and getattr(r2.error, "reason", None) == "deadline"
    assert eng.deadline_rejected == 1
    assert eng.admission.rejected_reasons.get("deadline") == 1


def test_generous_deadline_is_not_reaped(rng):
    q, rels = _triangle(seed=7)
    eng = _fast(JoinServeEngine(slots=2))
    req = eng.submit(q, rels, {"x": 3}, deadline_ms=60_000.0)
    eng.run()
    assert req.done and req.error is None
    assert req.result == _oracle(q, rels, 3)
    assert eng.deadline_rejected == 0


# ---- out-of-band mutation (version skew) -------------------------------


def test_mutation_skew_counted_and_warned_once(rng):
    q, rels = _triangle(seed=8, n=200)
    r = rels["R"]
    relcache.append(r, {v: np.asarray([1], r.columns[v].dtype) for v in r.schema})
    before = relcache.oob_swaps()
    relcache.reset_oob_warning()
    with faults.inject("mutation_skew", rel=r), pytest.warns(
        RuntimeWarning, match="out-of-band column swap"
    ):
        got = compiled_free_join(q, rels, agg="count")
    assert got == free_join(q, {a: relcache.live_relation(x) for a, x in rels.items()},
                            agg="count")
    assert relcache.oob_swaps() == before + 1
    # the warning is one-shot per process: a second skew only counts
    relcache.append(r, {v: np.asarray([2], r.columns[v].dtype) for v in r.schema})
    with faults.inject("mutation_skew", rel=r), warnings.catch_warnings():
        warnings.simplefilter("error")
        compiled_free_join(q, rels, agg="count")
    assert relcache.oob_swaps() == before + 2


def test_standing_query_recovers_eager_then_reconverges(rng):
    """A device fault mid-refresh degrades the standing query to the eager
    oracle (result still correct, degraded_to set); the next clean refresh
    rebuilds the compiled pipeline and clears the flag."""
    q, rels = _triangle(seed=9, n=300)
    eng = StandingQueryEngine()
    sq = eng.register(q, rels, {"x": 3})
    oracle = lambda: free_join(  # noqa: E731
        q, {a: relcache.live_relation(r) for a, r in rels.items()},
        agg="count", filters={"x": 3},
    )
    assert sq.result == oracle() and sq.degraded_to is None
    rng2 = np.random.default_rng(99)
    delta = {v: rng2.integers(0, 8, 40) for v in rels["R"].schema}
    with faults.inject("device_oom", times=1) as f:
        relcache.append(rels["R"], delta)
        eng.refresh()
    assert f.fired == 1
    assert eng.degraded_refreshes == 1
    assert sq.degraded_to == "eager"
    assert sq.result == oracle()
    v_deg = sq.result_version
    # clean refresh: the invalidated stages recompute on the compiled path
    eng.refresh()
    assert sq.degraded_to is None
    assert sq.result == oracle()
    assert sq.result_version > v_deg


# ---- the memory governor under live load -------------------------------


def test_governed_bytes_never_exceed_budget(rng):
    """The tentpole invariant: with a budget set, the governed device
    bytes stay under it across a stream of distinct workloads, and the
    governor provably made room by evicting (not merely by shedding)."""
    gov = membudget.GOVERNOR
    gov.reset()
    q, rels0 = _triangle(seed=20, n=800)
    assert compiled_free_join(q, rels0, agg="count") == free_join(q, rels0, agg="count")
    baseline = gov.live_bytes
    assert baseline > 0, "the compiled path must report its buffers"
    cap = int(baseline * 1.5)
    ev0 = gov.evictions
    with membudget.budget(cap):
        assert gov.live_bytes <= cap
        for seed in (21, 22, 23, 24):
            qq, rr = _triangle(seed=seed, n=800)
            assert compiled_free_join(qq, rr, agg="count") == free_join(
                qq, rr, agg="count"
            )
            assert gov.live_bytes <= cap, f"budget breached on seed {seed}"
    assert gov.evictions > ev0, "making room must have evicted cold entries"


def test_oversized_single_workload_sheds_but_answers(rng):
    """A budget smaller than one workload's buffers: everything sheds
    (served uncached / degraded), nothing crashes, the invariant holds."""
    gov = membudget.GOVERNOR
    gov.reset()
    q, rels = _triangle(seed=30, n=600)
    sheds0 = gov.sheds
    with membudget.budget(64):  # comically small
        assert compiled_free_join(q, rels, agg="count") == free_join(
            q, rels, agg="count"
        )
        assert gov.live_bytes <= 64
    assert gov.sheds > sheds0


# ---- mixed barrage ------------------------------------------------------


def test_mixed_fault_barrage_completes_every_request(rng):
    """Several fault kinds armed at once across a multi-tenant stream:
    every admitted request completes (possibly degraded), every answer
    matches the eager oracle."""
    q, rels = _triangle(seed=40)
    eng = _fast(JoinServeEngine(slots=2))
    consts = [1, 2, 3, 4, 5, 6]
    with faults.inject("compile_fail", times=1), faults.inject(
        "device_oom", times=1
    ), faults.inject("slow_dispatch", times=1, delay_s=0.001):
        reqs = [
            eng.submit(q, rels, {"x": c}, tenant=f"t{i % 3}")
            for i, c in enumerate(consts)
        ]
        eng.run()
    for req, c in zip(reqs, consts):
        assert req.done and req.error is None
        assert req.result == _oracle(q, rels, c)
    assert eng.faults_absorbed >= 1
    assert sum(eng.degraded.values()) >= 1
