"""Cost-based bushy plan enumeration and the measured-cardinality loop.

Three contracts: (1) plan choice never changes answers — greedy (level 0)
and exhaustive enumeration (level 2) agree with the eager engine on counts
and on full result sets, across acyclic and cyclic shapes; (2) the DP
finds bushy plans a greedy left-deep search cannot express when the
estimates already justify them; (3) the feedback loop re-plans a warm
query whose first run revealed a misestimated intermediate, and then
holds the new plan steady (no flip-flop)."""
import numpy as np

from repro.core import relcache
from repro.core.api import ExecOptions, compiled_free_join, free_join, to_sorted_tuples
from repro.core.optimizer import JoinOrderOptimizer, Stats, _tree_sig, optimize
from repro.core.plan import BinaryPlan
from repro.relational.relation import Relation
from repro.relational.schema import Atom, Query, triangle_query
from tests.conftest import rand_rel

# ---- query shapes: acyclic (chain, star) and cyclic (triangle, 4-cycle) --

SHAPES = {
    "chain4": Query(
        [
            Atom("R0", ("a", "b")),
            Atom("R1", ("b", "c")),
            Atom("R2", ("c", "d")),
            Atom("R3", ("d", "e")),
        ]
    ),
    "star3": Query(
        [Atom("S0", ("h", "a")), Atom("S1", ("h", "b")), Atom("S2", ("h", "c"))]
    ),
    "triangle": triangle_query(),
    "cycle4": Query(
        [
            Atom("A", ("x", "y")),
            Atom("B", ("y", "z")),
            Atom("C", ("z", "w")),
            Atom("D", ("w", "x")),
        ]
    ),
}


def _instance(q, rng):
    sizes = rng.integers(5, 40, len(q.atoms))
    doms = rng.integers(2, 7, len(q.atoms))
    return {
        a.alias: rand_rel(rng, a.alias, a.vars, int(n), int(d))
        for a, n, d in zip(q.atoms, sizes, doms)
    }


def test_enumerated_plans_match_greedy_and_eager(rng):
    """Property-style sweep: for every shape, level-0 (greedy) and level-2
    (exhaustive DP) compiled counts equal the eager oracle, and the level-2
    full result set is tuple-for-tuple the eager one."""
    for name, q in SHAPES.items():
        rels = _instance(q, rng)
        want = free_join(q, rels, agg="count")
        got0 = compiled_free_join(q, rels, agg="count", options=ExecOptions(optimize_level=0))
        got2 = compiled_free_join(q, rels, agg="count", options=ExecOptions(optimize_level=2))
        assert got0 == want, f"{name}: greedy plan changed the count"
        assert got2 == want, f"{name}: enumerated plan changed the count"
        full = compiled_free_join(q, rels, agg=None, options=ExecOptions(optimize_level=2))
        assert to_sorted_tuples(full, q.head) == to_sorted_tuples(
            free_join(q, rels), q.head
        ), f"{name}: enumerated plan changed the result set"


def test_budget_exhaustion_falls_back_to_greedy(rng):
    """An enumeration budget too small to finish the DP degrades to the
    greedy tree instead of an arbitrary partial winner."""
    q = SHAPES["chain4"]
    rels = _instance(q, rng)
    stats = Stats(rels)
    greedy = optimize(q, rels, stats=stats)
    starved = JoinOrderOptimizer(level=1, budget=1).choose(q, rels, stats=stats)
    assert _tree_sig(starved) == _tree_sig(greedy)


def test_enumerator_picks_bushy_on_selective_ends(rng):
    """Chain with selective end joins and a dense middle join: the greedy
    left-deep search must drag the dense intermediate through every later
    stage, while the DP can bracket it — (A⋈B)⋈(C⋈D) — and the device
    cost model prefers that. Counts agree regardless."""
    n = 400
    rels = {
        "A": Relation("A", {"a": rng.integers(0, 50, n), "b": rng.integers(0, 200, n)}),
        "B": Relation("B", {"b": rng.integers(0, 200, n), "c": rng.integers(0, 4, n)}),
        "C": Relation("C", {"c": rng.integers(0, 4, n), "d": rng.integers(0, 200, n)}),
        "D": Relation("D", {"d": rng.integers(0, 200, n), "e": rng.integers(0, 50, n)}),
    }
    q = Query(
        [Atom("A", ("a", "b")), Atom("B", ("b", "c")), Atom("C", ("c", "d")), Atom("D", ("d", "e"))]
    )
    stats = Stats(rels)
    greedy = optimize(q, rels, stats=stats)
    chosen = JoinOrderOptimizer(level=2).choose(q, rels, stats=stats)
    assert _tree_sig(chosen) != _tree_sig(greedy)
    assert isinstance(chosen, BinaryPlan)
    assert isinstance(chosen.left, BinaryPlan) and isinstance(chosen.right, BinaryPlan), (
        f"expected a bushy bracketing, got {chosen}"
    )
    want = free_join(q, rels, agg="count")
    assert compiled_free_join(q, rels, agg="count", options=ExecOptions(optimize_level=0)) == want
    assert compiled_free_join(q, rels, agg="count", options=ExecOptions(optimize_level=2)) == want


def _skewed_triangle(rng, n=200):
    """x and z are uniform (honest estimates, and deliberately asymmetric —
    d_x=20 vs d_z=10 — so exactly one alternative first join is cheapest);
    y has ~40 distinct values but 80% of its mass on one, so the
    per-variable distinct-count estimator prices R⋈S as the *cheapest*
    first join when it is by far the worst."""

    def skewed(n):
        v = rng.integers(0, 1000, n)
        v[rng.random(n) < 0.8] = 0
        return v

    rels = {
        "R": Relation("R", {"x": rng.integers(0, 20, n), "y": skewed(n)}),
        "S": Relation("S", {"y": skewed(n), "z": rng.integers(0, 10, n)}),
        "T": Relation("T", {"z": rng.integers(0, 10, n), "x": rng.integers(0, 20, n)}),
    }
    return triangle_query(), rels


def test_replan_after_misestimated_first_run(rng):
    """The acceptance bar for the feedback loop: a correlated-skew triangle
    whose estimates pick R⋈S first; the first run measures the real
    intermediate (~30x the estimate) and records it in relcache.FEEDBACK;
    the second call at optimize_level=2 re-plans away from it; the third
    call keeps the new plan (measurements now agree with costs — no
    flip-flop)."""
    relcache.FEEDBACK.clear()
    q, rels = _skewed_triangle(rng)
    opts = ExecOptions(optimize_level=2)
    want = free_join(q, rels, agg="count")

    info1 = {}
    assert compiled_free_join(q, rels, agg="count", options=opts, info=info1) == want
    assert len(relcache.FEEDBACK) > 0, "the run must record measured cardinalities"
    plan1 = _tree_sig(info1["plan_tree"])

    info2 = {}
    assert compiled_free_join(q, rels, agg="count", options=opts, info=info2) == want
    plan2 = _tree_sig(info2["plan_tree"])
    assert plan2 != plan1, "measured cardinalities must displace the misestimated plan"

    info3 = {}
    assert compiled_free_join(q, rels, agg="count", options=opts, info=info3) == want
    assert _tree_sig(info3["plan_tree"]) == plan2, "the adopted plan must be stable"


def test_default_level_pins_first_choice(rng):
    """At the default optimize_level=1 the same misestimated triangle keeps
    its first plan (and therefore its compiled runner) on warm calls: plan
    pinning is what makes serving's one-compile contract safe."""
    relcache.FEEDBACK.clear()
    q, rels = _skewed_triangle(rng)
    opts = ExecOptions(optimize_level=1)
    info1, info2 = {}, {}
    c1 = compiled_free_join(q, rels, agg="count", options=opts, info=info1)
    c2 = compiled_free_join(q, rels, agg="count", options=opts, info=info2)
    assert c1 == c2
    assert _tree_sig(info1["plan_tree"]) == _tree_sig(info2["plan_tree"])
    assert info2["runner"] is info1["runner"]


def test_cardfeedback_rtol_and_lifetime():
    """Unit contract of the store: re-recording within rtol is a no-op (no
    version churn -> no spurious re-planning); a material change bumps the
    version; entries die with their relations."""
    fb = relcache.CardFeedback(rtol=1.25)
    r = Relation("R", {"x": np.arange(4)})
    s = Relation("S", {"x": np.arange(4)})
    specs = [(r, ("x",)), (s, ("x",))]
    fb.record(specs, 100.0)
    v0 = fb.version
    assert fb.lookup(specs) == 100.0
    fb.record(specs, 110.0)  # within rtol: ignored
    assert fb.version == v0 and fb.lookup(specs) == 100.0
    fb.record(specs, 400.0)  # material: replaces and bumps
    assert fb.version > v0 and fb.lookup(specs) == 400.0
    # order of the spec list is canonicalized away
    assert fb.lookup([(s, ("x",)), (r, ("x",))]) == 400.0
    assert len(fb) == 1
    del specs, s
    import gc

    gc.collect()
    assert len(fb) == 0, "entries must die with their relations"
