"""Delta trie builds over mutating relations (the PR 9 storage contract).

What these tests lock down:

* Parity: any interleaving of relcache.append / delete / compact leaves
  compiled_free_join agreeing with the eager engine run over the live
  snapshot — counts AND agg=None tuples (pads and tombstones must weigh
  nothing and never surface).
* Incrementality: a warm append is served by ONE delta merge — the cached
  StaticTrie's builds counter does not move, only delta_merges does; a
  delete is a tombstone weight refresh, never a rebuild.
* Compaction: dropping below the live/total threshold triggers a real
  rebuild that physically drops dead rows, after which results still match.
* Shape stability: steady-state same-bucket appends reuse the merge
  program — the jit cache stops growing after the two-append warmup (the
  first merge adopts the unpadded cold trie, so its static signature is
  unique; from the second append on, shapes are fixed).
"""
import numpy as np
import pytest

from repro.core import compiled_free_join, free_join, relcache, to_sorted_tuples
from repro.core.compiled import TRIE_CACHE, _merge_append_jit
from repro.relational.schema import Atom, Query, triangle_query
from tests.conftest import rand_rel


def _oracle(q, rels, agg):
    live = {a: relcache.live_relation(r) for a, r in rels.items()}
    return free_join(q, live, agg=agg)


def _delta(rng, vars_, n, dom):
    return {v: rng.integers(0, dom, n).astype(np.int32) for v in vars_}


def _check_parity(q, rels):
    assert compiled_free_join(q, rels, agg="count") == _oracle(q, rels, "count")
    got = compiled_free_join(q, rels, agg=None)
    assert to_sorted_tuples(got, q.head) == to_sorted_tuples(_oracle(q, rels, None), q.head)


# ---- parity under random interleaved mutations ----------------------------


def test_interleaved_mutations_match_oracle(rng):
    """Randomly interleave appends, deletes, and forced compactions on all
    three triangle relations; the compiled engine must match the eager
    engine over the live snapshot at every step."""
    q = triangle_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 120, 8) for a in q.atoms}
    _check_parity(q, rels)  # cold build before any mutation

    aliases = list(rels)
    for step in range(12):
        alias = aliases[int(rng.integers(len(aliases)))]
        rel = rels[alias]
        op = int(rng.integers(3))
        if op == 0:
            relcache.append(rel, _delta(rng, rel.schema, int(rng.integers(1, 60)), 8))
        elif op == 1:
            n = rel.num_rows
            k = int(rng.integers(1, max(2, n // 4)))
            relcache.delete(rel, rng.choice(n, size=min(k, n), replace=False))
        else:
            relcache.compact(rel)
        _check_parity(q, rels)


def test_append_new_keys_surface_in_tuples(rng):
    """Appended rows with never-before-seen keys must appear in agg=None
    output (regression guard for distinct/key-bits memo priming)."""
    q = Query([Atom("R", ("x", "y")), Atom("S", ("y", "z"))])
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 50, 6) for a in q.atoms}
    _check_parity(q, rels)
    # keys far outside the cold domain, matched across both relations
    relcache.append(rels["R"], {"x": np.int32([777]), "y": np.int32([888])})
    relcache.append(rels["S"], {"y": np.int32([888]), "z": np.int32([999])})
    got = compiled_free_join(q, rels, agg=None)
    tuples = to_sorted_tuples(got, q.head)
    assert (777, 888, 999) in tuples
    assert tuples == to_sorted_tuples(_oracle(q, rels, None), q.head)


# ---- incrementality counters ----------------------------------------------


def test_append_is_one_delta_merge_zero_rebuilds(rng):
    """The acceptance contract: a warm append costs one delta merge. The
    trie cache's builds counter (every full StaticTrie construction routed
    through the cache) must not move; delta_merges must."""
    q = triangle_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 200, 9) for a in q.atoms}
    want = _oracle(q, rels, "count")
    assert compiled_free_join(q, rels, agg="count") == want  # cold: builds

    for _ in range(3):
        builds0, merges0 = TRIE_CACHE.builds, TRIE_CACHE.delta_merges
        relcache.append(rels["R"], _delta(rng, rels["R"].schema, 40, 9))
        got = compiled_free_join(q, rels, agg="count")
        assert got == _oracle(q, rels, "count")
        assert TRIE_CACHE.builds == builds0, "append must not trigger a full trie build"
        assert TRIE_CACHE.delta_merges >= merges0 + 1


def test_delete_is_tombstone_refresh_zero_rebuilds(rng):
    """A delete (above the compaction threshold) refreshes cached weights
    in place: no trie build, no delta merge, tombstone_refreshes moves."""
    q = triangle_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 200, 9) for a in q.atoms}
    assert compiled_free_join(q, rels, agg="count") == _oracle(q, rels, "count")

    builds0 = TRIE_CACHE.builds
    merges0 = TRIE_CACHE.delta_merges
    tomb0 = TRIE_CACHE.tombstone_refreshes
    relcache.delete(rels["S"], np.arange(10))
    assert compiled_free_join(q, rels, agg="count") == _oracle(q, rels, "count")
    assert TRIE_CACHE.builds == builds0, "tombstone delete must not rebuild the trie"
    assert TRIE_CACHE.delta_merges == merges0
    assert TRIE_CACHE.tombstone_refreshes >= tomb0 + 1


def test_auto_compaction_below_live_ratio(rng):
    """Deleting past the live/total threshold triggers compaction: the
    physical relation shrinks to its live rows and results still match."""
    q = Query([Atom("R", ("x", "y")), Atom("S", ("y", "z"))])
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 100, 6) for a in q.atoms}
    assert compiled_free_join(q, rels, agg="count") == _oracle(q, rels, "count")

    rel = rels["R"]
    relcache.delete(rel, np.arange(80))  # live/total = 0.2 < default 0.5
    st = relcache.mutation_state(rel)
    assert st is not None and st.compactions >= 1
    assert rel.num_rows == 20, "compaction must drop dead rows physically"
    assert len(next(iter(rel.columns.values()))) == 20
    _check_parity(q, rels)


# ---- shape stability -------------------------------------------------------


def test_steady_state_appends_do_not_retrace(rng):
    """Within one capacity bucket, repeated same-size appends reuse the
    compiled merge program. Warmup is TWO appends (the first merge adopts
    the unpadded cold trie, so its static signature differs); after that
    the merge jit cache must stop growing."""
    if not hasattr(_merge_append_jit, "_cache_size"):
        pytest.skip("jax version without jit cache introspection")
    q = Query([Atom("R", ("x", "y")), Atom("S", ("y", "z"))])
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 300, 9) for a in q.atoms}
    assert compiled_free_join(q, rels, agg="count") == _oracle(q, rels, "count")

    def delta16():
        # pin the delta's max key: the merge signature includes the delta's
        # sort bit width, so a delta that happens to top out at a shorter
        # key would retrace legitimately (fewer sort passes)
        d = _delta(rng, rels["R"].schema, 16, 9)
        return {v: np.concatenate([c[:-1], np.int32([8])]) for v, c in d.items()}

    for _ in range(2):  # warmup: adoption merge + first steady-state merge
        relcache.append(rels["R"], delta16())
        compiled_free_join(q, rels, agg="count")
    size0 = _merge_append_jit._cache_size()
    for _ in range(4):
        relcache.append(rels["R"], delta16())
        assert compiled_free_join(q, rels, agg="count") == _oracle(q, rels, "count")
    assert _merge_append_jit._cache_size() == size0, "steady-state append retraced the merge"


# ---- mutation-state bookkeeping -------------------------------------------


def test_live_relation_and_size_track_mutations(rng):
    rel = rand_rel(rng, "R", ("x", "y"), 40, 5)
    relcache.append(rel, {"x": np.int32([1, 2]), "y": np.int32([3, 4])})
    assert relcache.live_size(rel) == 42
    relcache.delete(rel, np.int32([0, 1]))
    assert relcache.live_size(rel) == 40
    live = relcache.live_relation(rel)
    assert len(next(iter(live.columns.values()))) == 40
    # snapshot is cached per version
    assert relcache.live_relation(rel) is live
