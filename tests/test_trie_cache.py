"""Cross-call trie cache + weakref registry (the PR 5 build/probe split).

The contract under test: a repeated identical compiled_free_join call is
all cache hits — zero trie builds, zero build_table calls, zero np.unique,
zero recompiles; a replaced column or relation rebuilds exactly what
changed; weighted (stage-output) tries are never served from the cache;
lazy builds construct hash tables only for the levels a schedule actually
probes; and every identity-keyed cache entry dies with its relation.
"""
import gc

import numpy as np
import pytest

import repro.core.compiled as compiled_mod
from repro.core import compiled_free_join, free_join
from repro.core.compiled import TRIE_CACHE, _LevelOps, device_columns
from repro.core.plan import BinaryPlan
from repro.relational.relation import Relation
from repro.relational.schema import Atom, Query, triangle_query
from tests.conftest import rand_rel


def _counters():
    c = TRIE_CACHE
    return (c.builds, c.table_builds, c.hits, c.order_shares)


# ---- the acceptance assertion: warm call performs zero build work ---------


def test_second_identical_call_zero_builds(rng, monkeypatch):
    """The second identical compiled_free_join call must perform zero trie
    builds and zero build_table calls — probe cost only."""
    q = triangle_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 60, 9) for a in q.atoms}
    want = free_join(q, rels, agg="count")

    assert compiled_free_join(q, rels, agg="count") == want  # cold: builds
    builds0, tables0, hits0, _ = _counters()

    # lock the warm path with counters on the build fns: neither the trie
    # build nor any hash-table build may run again
    build_calls, table_calls = [0], [0]
    orig_build, orig_table = compiled_mod.build_trie, compiled_mod.ops.build_table
    monkeypatch.setattr(
        compiled_mod,
        "build_trie",
        lambda *a, **k: (build_calls.__setitem__(0, build_calls[0] + 1), orig_build(*a, **k))[1],
    )
    monkeypatch.setattr(
        compiled_mod.ops,
        "build_table",
        lambda *a, **k: (table_calls.__setitem__(0, table_calls[0] + 1), orig_table(*a, **k))[1],
    )
    assert compiled_free_join(q, rels, agg="count") == want  # warm
    assert build_calls[0] == 0, "warm call must not build any trie"
    assert table_calls[0] == 0, "warm call must not build any hash table"
    builds1, tables1, hits1, _ = _counters()
    assert (builds1, tables1) == (builds0, tables0)
    assert hits1 > hits0, "the warm call must actually hit the cache"


def test_warm_call_zero_planning_host_work(rng, monkeypatch):
    """Warm planning is dict lookups: no np.unique, no executor recompile
    (the runner itself is reused)."""
    q = triangle_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 50, 8) for a in q.atoms}
    info = {}
    cold = compiled_free_join(q, rels, agg="count", info=info)
    compiles0 = info["runner"].compiles

    uniq = [0]
    orig_unique = np.unique
    monkeypatch.setattr(
        np, "unique", lambda *a, **k: (uniq.__setitem__(0, uniq[0] + 1), orig_unique(*a, **k))[1]
    )
    info2 = {}
    assert compiled_free_join(q, rels, agg="count", info=info2) == cold
    assert uniq[0] == 0, f"warm planning must not np.unique, got {uniq[0]}"
    assert info2["runner"] is info["runner"], "the runner must be reused"
    assert info2["runner"].compiles == compiles0, "no recompile on the warm call"


# ---- invalidation: replaced columns / relations rebuild -------------------


def test_replaced_column_rebuilds(rng):
    q = triangle_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 60, 9) for a in q.atoms}
    compiled_free_join(q, rels, agg="count")
    builds0 = TRIE_CACHE.builds
    # replacing a column array (same content, new object) must invalidate
    # exactly R's cached trie — identity, not content, is the cheap check
    rels["R"].columns["x"] = rels["R"].columns["x"].copy()
    want = free_join(q, rels, agg="count")
    assert compiled_free_join(q, rels, agg="count") == want
    assert TRIE_CACHE.builds == builds0 + 1, "exactly the touched relation rebuilds"


def test_replaced_relation_rebuilds_and_changes_result(rng):
    from repro.core import optimize

    q = triangle_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 60, 9) for a in q.atoms}
    tree = optimize(q, rels)  # pin the plan: only the data changes below
    compiled_free_join(q, rels, tree, agg="count")
    builds0 = TRIE_CACHE.builds
    rels["S"] = rand_rel(rng, "S", ("y", "z"), 80, 7)  # new object, new data
    want = free_join(q, rels, tree, agg="count")
    assert compiled_free_join(q, rels, tree, agg="count") == want
    assert TRIE_CACHE.builds == builds0 + 1


# ---- weighted stage tries are never served from the cache -----------------


def test_weighted_tries_refused_by_cache(rng):
    import jax.numpy as jnp

    rel = rand_rel(rng, "R", ("x", "y"), 30, 5)
    dev = device_columns(rel)
    lops = _LevelOps((("x",), ("y",)), (True, False))
    with pytest.raises(AssertionError, match="never cached"):
        TRIE_CACHE.get(rel, dev, lops, mult=jnp.ones(30, jnp.int32))


def test_bushy_stage_tries_rebuilt_per_call_base_tries_cached(rng):
    """A bushy chain's stage-output tries are in-graph per call (weighted —
    excluded from reuse); the base relations still hit the cache on the
    second call, and results stay exact."""
    q = Query(
        [Atom("A", ("x", "y")), Atom("B", ("y", "z")), Atom("C", ("z", "w")), Atom("D", ("w", "u"))]
    )
    tree = BinaryPlan(BinaryPlan(q.atoms[0], q.atoms[1]), BinaryPlan(q.atoms[2], q.atoms[3]))
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 40, 8) for a in q.atoms}
    want = free_join(q, rels, tree, agg="count")
    assert compiled_free_join(q, rels, tree, agg="count") == want
    builds0, tables0, hits0, _ = _counters()
    assert compiled_free_join(q, rels, tree, agg="count") == want
    builds1, tables1, hits1, _ = _counters()
    assert (builds1, tables1) == (builds0, tables0), "base tries all cached"
    assert hits1 > hits0


# ---- lazy per-level tables + prefix-compatible order sharing --------------


def test_lazy_build_tables_only_for_probed_levels(rng):
    rel = rand_rel(rng, "R", ("x", "y"), 50, 6)
    dev = device_columns(rel)
    t1 = TRIE_CACHE.get(rel, dev, _LevelOps((("x",), ("y",)), (True, False)))
    assert t1.tables[0] is not None, "probed level must have its table"
    assert t1.tables[1] is None, "unprobed level must not build a table"
    builds0, tables0 = TRIE_CACHE.builds, TRIE_CACHE.table_builds
    # a second schedule probing the skipped level adds exactly one table —
    # no re-sort, no structure rebuild
    t2 = TRIE_CACHE.get(rel, dev, _LevelOps((("x",), ("y",)), (True, True)))
    assert t2.tables[0] is not None and t2.tables[1] is not None
    assert TRIE_CACHE.builds == builds0, "no full rebuild for a new probe pattern"
    assert TRIE_CACHE.table_builds == tables0 + 1
    assert t2.order is t1.order, "one sort order shared across probe patterns"
    # the original probe pattern still sees only its own table
    t1b = TRIE_CACHE.get(rel, dev, _LevelOps((("x",), ("y",)), (True, False)))
    assert t1b.tables[1] is None


def test_cover_only_trie_not_served_to_probing_schedule(rng):
    """Trivial (cover-only) tries have no tables and no order; a schedule
    that probes the same single level must get its own sorted+tabled build,
    and both flavors coexist in the cache."""
    import jax.numpy as jnp

    rel = rand_rel(rng, "R", ("x", "y"), 40, 6)
    dev = device_columns(rel)
    cover = TRIE_CACHE.get(rel, dev, _LevelOps((("x", "y"),), (False,)))
    assert cover.trivial and cover.tables is None
    probed = TRIE_CACHE.get(rel, dev, _LevelOps((("x", "y"),), (True,)))
    assert not probed.trivial and probed.tables[0] is not None
    # the probing trie actually probes (would TypeError on a trivial serve)
    hit = probed.probe(0, jnp.zeros(4, jnp.int32), [dev["x"][:4], dev["y"][:4]])
    assert hit.shape == (4,)
    # and the cover-only request still gets the trivial flavor back
    again = TRIE_CACHE.get(rel, dev, _LevelOps((("x", "y"),), (False,)))
    assert again.trivial


def test_prefix_compatible_level_sequences_share_order(rng):
    rel = rand_rel(rng, "R", ("x", "y"), 50, 6)
    dev = device_columns(rel)
    TRIE_CACHE.get(rel, dev, _LevelOps((("x",), ("y",)), (True, True)))
    shares0 = TRIE_CACHE.order_shares
    # single flat level over the same var prefix: new layout, shared sort
    t = TRIE_CACHE.get(rel, dev, _LevelOps((("x", "y"),), (True,)))
    assert TRIE_CACHE.order_shares == shares0 + 1
    assert t.tables[0] is not None


def test_runner_cache_safe_across_head_projections(rng):
    """Queries differing only in output head: the compiled (and eager)
    agg=None contract returns every bound var — projection happens
    downstream via to_sorted_tuples — so runner reuse across heads is
    safe. Lock the downstream results against the eager engine for both
    heads; the runner key also carries the stage heads so this stays
    correct if stage planning ever starts propagating user projections."""
    from repro.core import to_sorted_tuples

    atoms = [Atom("R", ("x", "y")), Atom("S", ("y", "z"))]
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 40, 6) for a in atoms}
    q_full = Query(list(atoms))
    q_proj = Query(list(atoms), head=("x",))
    for q in (q_full, q_proj):
        got = compiled_free_join(q, rels, agg=None)
        want = free_join(q, rels, agg=None)
        assert to_sorted_tuples(got, q.head) == to_sorted_tuples(want, q.head)


# ---- registry lifetime: entries die with their relations ------------------


def test_runner_and_trie_cache_entries_die_with_relations(rng):
    from repro.core.api import _runner_cache
    from repro.core.relcache import REGISTRY

    q = triangle_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 40, 8) for a in q.atoms}
    gc.collect()  # flush entries pending from earlier tests' dead relations
    n0 = len(_runner_cache)
    compiled_free_join(q, rels, agg="count")
    assert len(_runner_cache) > n0
    assert REGISTRY._spaces.get(rels["R"]) is not None
    del rels
    gc.collect()
    # weakref finalizers evicted the runner; the registry dropped the
    # per-relation namespaces with the objects (<=: the collect may also
    # sweep other tests' stale entries)
    assert len(_runner_cache) <= n0


def test_device_columns_revalidated_by_column_identity(rng):
    rel = rand_rel(rng, "R", ("x", "y"), 30, 5)
    d1 = device_columns(rel)
    d2 = device_columns(rel)
    assert d1["x"] is d2["x"], "same column object -> same upload"
    rel.columns["x"] = rel.columns["x"].copy()
    d3 = device_columns(rel)
    assert d3["x"] is not d1["x"], "replaced column -> fresh upload"
    assert d3["y"] is d1["y"], "untouched column keeps its upload"
