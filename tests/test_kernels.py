"""Per-kernel shape/dtype sweeps, Pallas (interpret) vs pure-jnp oracles."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import ops, ref


@pytest.mark.parametrize("n,k,q", [(1, 1, 5), (17, 2, 64), (300, 3, 700), (1000, 1, 2048)])
@pytest.mark.parametrize("impl", ["jnp", "pallas_interpret"])
def test_hash_probe_vs_oracle(n, k, q, impl, rng):
    keys = np.unique(rng.integers(0, 10**6, (2 * n, k)).astype(np.int32), axis=0)[:n]
    table = ops.build_table(jnp.asarray(keys))
    assert int(table.max_disp) < 32
    qs = np.vstack(
        [keys[rng.integers(0, len(keys), q // 2)],
         rng.integers(10**6, 2 * 10**6, (q - q // 2, k)).astype(np.int32)]
    )
    want = ref.hash_probe_ref(jnp.asarray(keys), jnp.asarray(qs))
    got = ops.probe(table, jnp.asarray(qs), impl=impl)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("m,n", [(1, 1), (100, 37), (1000, 999)])
@pytest.mark.parametrize("impl", ["jnp", "pallas_interpret"])
def test_intersect_vs_oracle(m, n, impl, rng):
    b = np.unique(rng.integers(0, 10**5, n).astype(np.int32))
    a = np.concatenate(
        [
            b[rng.integers(0, len(b), m // 2 + 1)],
            rng.integers(10**5, 2 * 10**5, m // 2).astype(np.int32),
        ]
    )
    wm, wp = ref.intersect_ref(jnp.asarray(a), jnp.asarray(b))
    gm, gp = ops.intersect_sorted(jnp.asarray(a), jnp.asarray(b), impl=impl)
    np.testing.assert_array_equal(np.asarray(gm), np.asarray(wm))
    np.testing.assert_array_equal(np.asarray(gp), np.asarray(wp))


@pytest.mark.parametrize("g,f,cap", [(5, 8, 1024), (50, 100, 2048)])
@pytest.mark.parametrize("impl", ["jnp", "pallas_interpret"])
def test_csr_expand_vs_oracle(g, f, cap, impl, rng):
    counts = rng.integers(0, 7, g).astype(np.int32)
    offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
    groups = rng.integers(0, g, f).astype(np.int32)
    wfr, wm, wv, wt = ref.csr_expand_ref(jnp.asarray(offsets), jnp.asarray(groups), cap)
    gfr, gm, gv, gt = ops.csr_expand_capped(
        jnp.asarray(offsets), jnp.asarray(groups), cap, impl=impl
    )
    np.testing.assert_array_equal(np.asarray(gfr), np.asarray(wfr))
    np.testing.assert_array_equal(np.asarray(gm), np.asarray(wm))
    assert int(gt) == int(wt)


def test_expand_counted_zero_counts():
    base = jnp.asarray(np.array([0, 5, 9], np.int32))
    counts = jnp.asarray(np.array([2, 0, 3], np.int32))
    fr, member, valid, total = ops.expand_counted(base, counts, 8)
    assert int(total) == 5
    np.testing.assert_array_equal(np.asarray(fr[:5]), [0, 0, 2, 2, 2])
    np.testing.assert_array_equal(np.asarray(member[:5]), [0, 1, 9, 10, 11])


@pytest.mark.parametrize(
    "n,doms", [(1, (4,)), (64, (16, 300)), (1000, (7, 5, 900)), (4096, (2, 2))]
)
@pytest.mark.parametrize("impl", ["jnp", "pallas_interpret"])
def test_segmented_sort_vs_lexsort(n, doms, impl, rng):
    from repro.kernels.radix_sort import segmented_sort

    cols = [rng.integers(0, d, n).astype(np.int32) for d in doms]
    bits = tuple(max(1, int(d - 1).bit_length()) for d in doms)
    want = ref.segmented_sort_ref(cols)
    got = segmented_sort([jnp.asarray(c) for c in cols], bits, impl=impl)
    # stable LSD passes within refining segments reproduce the exact
    # lexsort permutation, not just the grouping
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("impl", ["jnp", "pallas_interpret"])
def test_segmented_sort_presorted_prefix(impl, rng):
    """Seeding with a cached prefix order (the trie cache's order sharing)
    must land on the same permutation as the full sort."""
    from repro.kernels.radix_sort import segmented_sort

    n = 777
    c0 = jnp.asarray(rng.integers(0, 30, n).astype(np.int32))
    c1 = jnp.asarray(rng.integers(0, 500, n).astype(np.int32))
    full = segmented_sort([c0, c1], (5, 9), impl=impl)
    pre = segmented_sort([c0], (5,), impl=impl)
    seeded = segmented_sort([c0, c1], (5, 9), impl=impl, init_order=pre, presorted=1)
    np.testing.assert_array_equal(np.asarray(seeded), np.asarray(full))
    # a donor sorted by MORE vars: everything is presorted, zero passes
    both = segmented_sort([c0, c1], (5, 9), impl=impl, init_order=full, presorted=2)
    np.testing.assert_array_equal(np.asarray(both), np.asarray(full))


def test_segmented_sort_duplicate_heavy(rng):
    from repro.kernels.radix_sort import segmented_sort

    n = 2048
    cols = [np.zeros(n, np.int32), rng.integers(0, 3, n).astype(np.int32)]
    want = ref.segmented_sort_ref(cols)
    got = segmented_sort([jnp.asarray(c) for c in cols], (1, 2))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_build_table_adversarial_same_slot(rng):
    # many keys whose mixed hash collides in low bits is handled by probing
    keys = (np.arange(512, dtype=np.int32) * 64)[:, None]
    t = ops.build_table(jnp.asarray(keys))
    got = ops.probe(t, jnp.asarray(keys))
    np.testing.assert_array_equal(np.asarray(got), np.arange(512))
