"""StandingQueryEngine: incremental maintenance over streaming ingest.

The contract under test (PR 9 serving layer): a registered query's result
tracks the eager oracle across ingests; a refresh with no mutations skips
EVERY stage; an ingest into a relation only one stage reads leaves the
other stages replaying cached device buffers (fingerprint skip); and two
queries of one template share per-stage runners.
"""
import numpy as np

from repro.core import free_join, relcache, to_sorted_tuples
from repro.core.api import ExecOptions
from repro.core.plan import BinaryPlan
from repro.relational.relation import Relation
from repro.relational.schema import Atom, Query
from repro.serve import StandingQueryEngine
from tests.conftest import rand_rel


def chain4(rng, n=200, dom=12):
    q = Query(
        [Atom("R", ("a", "b")), Atom("S", ("b", "c")), Atom("T", ("c", "d")), Atom("U", ("d", "e"))]
    )
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, n, dom) for a in q.atoms}
    return q, rels


def _oracle(q, rels, agg="count"):
    live = {a: relcache.live_relation(r) for a, r in rels.items()}
    return free_join(q, live, agg=agg)


def _delta(rng, vars_, n, dom=12):
    return {v: rng.integers(0, dom, n).astype(np.int32) for v in vars_}


def test_standing_count_tracks_oracle_across_ingest(rng):
    q, rels = chain4(rng)
    eng = StandingQueryEngine(options=ExecOptions())
    sq = eng.register(q, rels, agg="count")
    assert sq.result == _oracle(q, rels)
    for _ in range(3):
        changed = eng.ingest(rels["U"], _delta(rng, ("d", "e"), 50))
        assert sq in changed
        assert sq.result == _oracle(q, rels)
    relcache.delete(rels["R"], np.arange(20))
    eng.refresh()
    assert sq.result == _oracle(q, rels)


def test_noop_refresh_skips_every_stage(rng):
    q, rels = chain4(rng)
    eng = StandingQueryEngine(options=ExecOptions())
    sq = eng.register(q, rels, agg="count")
    nstages = len(sq.states)
    recomputed0, skipped0 = eng.stages_recomputed, eng.stages_skipped
    assert eng.refresh() == []
    assert eng.stages_recomputed == recomputed0, "no-op refresh must not recompute"
    assert eng.stages_skipped == skipped0 + nstages
    assert sq.result == _oracle(q, rels)


def test_unchanged_stage_replays_cached_buffers(rng):
    """Force a bushy two-stage plan: (R⋈S) ⋈ (T⋈U). Mutating only R must
    leave the T⋈U stage skipped — its fingerprint (base column identity /
    mutation version) did not move."""
    q, rels = chain4(rng)
    a = {at.alias: at for at in q.atoms}
    tree = BinaryPlan(BinaryPlan(a["R"], a["S"]), BinaryPlan(a["T"], a["U"]))
    eng = StandingQueryEngine(options=ExecOptions())
    sq = eng.register(q, rels, agg="count", plan_tree=tree)
    nstages = len(sq.states)
    assert nstages >= 2
    assert sq.result == _oracle(q, rels)

    recomputed0, skipped0 = eng.stages_recomputed, eng.stages_skipped
    eng.ingest(rels["R"], _delta(rng, ("a", "b"), 40))
    assert sq.result == _oracle(q, rels)
    recomputed = eng.stages_recomputed - recomputed0
    skipped = eng.stages_skipped - skipped0
    assert skipped >= 1, "the stage not reading R must replay its cached buffers"
    assert recomputed < nstages
    assert recomputed + skipped == nstages


def test_materialized_standing_query(rng):
    q, rels = chain4(rng, n=120)
    eng = StandingQueryEngine(options=ExecOptions())
    sq = eng.register(q, rels, agg=None)
    assert to_sorted_tuples(sq.result, q.head) == to_sorted_tuples(_oracle(q, rels, None), q.head)
    eng.ingest(rels["T"], _delta(rng, ("c", "d"), 30))
    assert to_sorted_tuples(sq.result, q.head) == to_sorted_tuples(_oracle(q, rels, None), q.head)


def test_cotemplate_queries_share_runners(rng):
    """Two standing queries of the same shape share one per-stage runner
    set (the template cache), and both stay correct across ingest."""
    q, rels = chain4(rng, n=100)
    eng = StandingQueryEngine(options=ExecOptions())
    sq1 = eng.register(q, rels, agg="count")
    sq2 = eng.register(q, rels, agg="count")
    assert sq1.template.key == sq2.template.key
    assert len(eng._runners) == 1
    eng.ingest(rels["S"], _delta(rng, ("b", "c"), 40))
    want = _oracle(q, rels)
    assert sq1.result == want
    assert sq2.result == want


def test_filtered_standing_query(rng):
    """Equality filters ride the template's lifted constants: two standing
    queries differing only in the constant share runners and each tracks
    its own filtered oracle."""
    q = Query([Atom("R", ("a", "b")), Atom("S", ("b", "c"))])
    rels = {at.alias: rand_rel(rng, at.alias, at.vars, 150, 6) for at in q.atoms}
    eng = StandingQueryEngine(options=ExecOptions())
    sqs = {k: eng.register(q, rels, filters={"a": k}, agg="count") for k in (1, 3)}
    assert len(eng._runners) == 1

    def oracle(k):
        live = {a: relcache.live_relation(r) for a, r in rels.items()}
        keep = live["R"].columns["a"] == k
        fr = Relation("R", {v: c[keep] for v, c in live["R"].columns.items()})
        return free_join(q, {"R": fr, "S": live["S"]}, agg="count")

    for k, sq in sqs.items():
        assert sq.result == oracle(k)
    eng.ingest(rels["R"], _delta(rng, ("a", "b"), 60, dom=6))
    for k, sq in sqs.items():
        assert sq.result == oracle(k)
