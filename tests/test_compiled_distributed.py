"""Compiled (static-shape) engine + distributed HyperCube joins."""
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core import (
    binary2fj,
    compiled_free_join,
    factor,
    free_join,
    gj_plan,
    optimize,
    to_sorted_tuples,
)
from repro.core.compiled import count_query
from repro.core.distributed import (
    distributed_join_host,
    hypercube_shares,
    spmd_count,
)
from repro.core.plan import BinaryPlan
from repro.relational.oracle import join_oracle
from repro.relational.relation import Relation
from repro.relational.schema import Atom, Query, clover_query, triangle_query
from tests.conftest import rand_rel
from tests.test_capacity_compiled import four_cycle_query


@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("impl", ["jnp", "pallas_interpret"])
def test_compiled_count_triangle(seed, impl):
    rng = np.random.default_rng(seed)
    q = triangle_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 40, 8) for a in q.atoms}
    want = len(join_oracle(q, rels))
    fj = factor(binary2fj(q.atoms, q))
    got, ovf = count_query(fj, rels, [4096] * 4, impl=impl)
    assert not ovf and got == want


def test_compiled_count_gj_plan(rng):
    q = triangle_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 40, 8) for a in q.atoms}
    want = len(join_oracle(q, rels))
    got, ovf = count_query(gj_plan(q, ["x", "y", "z"]), rels, [4096] * 4)
    assert not ovf and got == want


def test_compiled_overflow_detected(rng):
    q = clover_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 60, 5) for a in q.atoms}
    fj = factor(binary2fj(q.atoms, q))
    _, ovf = count_query(fj, rels, [4] * 4)
    assert ovf


def test_compiled_bag_semantics():
    rels = {
        "R": Relation("R", {"x": np.array([1, 1, 1]), "a": np.array([5, 5, 7])}),
        "S": Relation("S", {"x": np.array([1, 1]), "b": np.array([9, 9])}),
    }
    q = Query([Atom("R", ("x", "a")), Atom("S", ("x", "b"))])
    fj = factor(binary2fj(q.atoms, q))
    got, ovf = count_query(fj, rels, [64] * 3)
    assert not ovf and got == 6


def test_hypercube_shares_triangle_is_cube():
    q = triangle_query()
    shares = hypercube_shares(q, {"R": 100, "S": 100, "T": 100}, 8)
    assert sorted(shares.values()) == [2, 2, 2]


def test_hypercube_shares_zero_variables():
    # regression: no exponent combos exist for a zero-variable query; the
    # all-ones assignment (every shard sees the whole input) must come back,
    # not None
    q = Query([Atom("R", ())])
    assert hypercube_shares(q, {"R": 5}, 4) == {}
    q2 = Query([Atom("R", ("x",)), Atom("S", ("x",))])
    shares = hypercube_shares(q2, {"R": 10, "S": 10}, 1)
    assert shares == {"x": 1}


def test_partition_covers_every_output(rng):
    q = triangle_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 60, 8) for a in q.atoms}
    want = len(join_oracle(q, rels))
    got = distributed_join_host(q, rels, num_shards=8, agg="count")
    assert got == want


def test_distributed_materialized(rng):
    q = triangle_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 40, 6) for a in q.atoms}
    out = distributed_join_host(q, rels, num_shards=4)
    got = sorted(zip(*(out[v] for v in q.head)))
    want = join_oracle(q, rels)
    assert [tuple(map(int, t)) for t in got] == want


def test_eager_compiled_distributed_agree_on_bushy_plan(rng):
    """Sec 5.4 regime: the hijacked optimizer emits a bushy balanced tree.
    All three execution paradigms must agree on it — the unified planning
    driver serves the compiled path's stages too."""
    q = four_cycle_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 50, 6) for a in q.atoms}
    bushy = optimize(q, rels, bad=True)
    assert isinstance(bushy, BinaryPlan) and isinstance(bushy.right, BinaryPlan)
    want = len(join_oracle(q, rels))
    assert free_join(q, rels, bushy, agg="count") == want
    assert compiled_free_join(q, rels, bushy, agg="count") == want
    assert distributed_join_host(q, rels, num_shards=4, plan_tree=bushy, agg="count") == want
    bound, mult = compiled_free_join(q, rels, bushy, agg=None)
    assert to_sorted_tuples((bound, mult), q.head) == join_oracle(q, rels)


# ---------------------------------------------------------------------------
# SPMD driver: planner-derived capacities + host-side overflow retry.
# A 1-shard mesh exercises the whole shard_map + psum + retry machinery on
# the single CPU device; the 8-device variant runs in the slow subprocess
# test below.
# ---------------------------------------------------------------------------


def test_spmd_count_planner_capacities(rng):
    q = triangle_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 80, 10) for a in q.atoms}
    want = len(join_oracle(q, rels))
    mesh = jax.make_mesh((1,), ("data",))
    fj = factor(binary2fj(q.atoms, q))
    info = {}
    got = spmd_count(q, rels, fj, None, mesh, info=info)
    assert got == want
    assert info["retries"] == 0, "planner capacities should not overflow here"
    assert info["cap_plan"].schedule is not None


def test_spmd_overflow_retry_exact_count(rng):
    """An undersized initial plan must never leak a sentinel: the retry loop
    outside the collective grows the offending node to its reported need and
    the exact (non-negative) count comes back."""
    q = triangle_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 80, 10) for a in q.atoms}
    want = free_join(q, rels, agg="count")
    mesh = jax.make_mesh((1,), ("data",))
    fj = factor(binary2fj(q.atoms, q))
    info = {}
    got = spmd_count(q, rels, fj, [16] * 4, mesh, info=info)
    assert got == want and got >= 0
    assert info["retries"] >= 1
    assert max(info["cap_plan"].capacities) > 16
    # need-based growth: a couple of retries at most, not a doubling ladder
    assert info["retries"] <= len(info["cap_plan"].capacities)


def test_spmd_count_empty_relation(rng):
    q = triangle_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 40, 8) for a in q.atoms}
    rels["S"] = Relation("S", {"y": np.zeros(0, np.int64), "z": np.zeros(0, np.int64)})
    mesh = jax.make_mesh((1,), ("data",))
    fj = factor(binary2fj(q.atoms, q))
    assert spmd_count(q, rels, fj, None, mesh) == 0


def test_spmd_caches_persist_across_instances(rng):
    """The hypercube partition (dense device fragments) and the grown
    CapacityPlan persist process-wide across SpmdCounter instances over the
    very same relation objects; different relation objects re-partition."""
    from repro.core.distributed import SpmdCounter

    q = triangle_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 300, 8) for a in q.atoms}
    mesh = jax.make_mesh((1,), ("data",))
    fj = factor(binary2fj(q.atoms, q))
    # a tiny safety factor undersizes the planned capacities, forcing the
    # first instance to learn (grow) the plan through the retry loop
    c1 = SpmdCounter(q, rels, fj, None, mesh, safety=1e-6)
    want = free_join(q, rels, agg="count")
    assert c1() == want
    assert c1.retries >= 1, "the undersized plan must actually grow"
    # second instance: same relations -> cached fragments + the grown plan,
    # so it starts overflow-free and never re-partitions
    c2 = SpmdCounter(q, rels, fj, None, mesh, safety=1e-6)
    assert c2._dense is c1._dense, "partition must be served from the cache"
    assert c2._tries is c1._tries, "per-shard tries must be served from the cache"
    assert c2.cap_plan == c1.cap_plan, "the grown plan must persist"
    assert c2() == want
    assert c2.retries == 0, "a persisted plan re-learns nothing"
    # fresh relation objects (same content) invalidate the identity check
    rels2 = {a.alias: Relation(a.alias, dict(rels[a.alias].columns)) for a in q.atoms}
    c3 = SpmdCounter(q, rels2, fj, None, mesh, safety=1e-6)
    assert c3._dense is not c1._dense
    assert c3._tries is not c1._tries
    assert c3() == want


def test_hypercube_shares_memoized():
    from repro.core.distributed import _shares_cache

    q = triangle_query()
    sizes = {"R": 12345, "S": 23456, "T": 34567}
    first = hypercube_shares(q, sizes, 8)
    key_count = len(_shares_cache)
    again = hypercube_shares(q, sizes, 8)
    assert again == first
    assert len(_shares_cache) == key_count, "second call must hit the memo"
    # the memo hands out copies: callers mutating shares can't poison it
    again["x"] = 99
    assert hypercube_shares(q, sizes, 8) == first


SPMD_SCRIPT = r"""
import numpy as np, jax
from repro.relational.schema import triangle_query
from repro.relational.relation import Relation
from repro.relational.oracle import join_oracle
from repro.core import binary2fj, factor
from repro.core.distributed import spmd_count  # has the shard_map compat alias
rng = np.random.default_rng(0)
q = triangle_query()
rels = {a.alias: Relation(a.alias, {v: rng.integers(0, 12, 120) for v in a.vars}) for a in q.atoms}
want = len(join_oracle(q, rels))
mesh = jax.make_mesh((8,), ("data",))
fj = factor(binary2fj(q.atoms, q))
got = spmd_count(q, rels, fj, [8192] * 4, mesh)  # manual capacities
assert got == want, (got, want)
info = {}
got = spmd_count(q, rels, fj, None, mesh, info=info)  # planner capacities
assert got == want, (got, want)
assert info["retries"] == 0, info
info = {}
got = spmd_count(q, rels, fj, [32] * 4, mesh, info=info)  # undersized: retry, no sentinel
assert got == want and got >= 0, (got, want)
assert info["retries"] >= 1, info
print("SPMD_OK", got)
"""


@pytest.mark.slow
def test_spmd_count_8_devices_subprocess():
    """shard_map + psum on 8 fake CPU devices (subprocess so the fake
    device count never leaks into this test session). Slow: compiles the
    whole executor once per device mesh in a fresh process."""
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=8", "PYTHONPATH": "src"}
    import os

    env = {**os.environ, **env}
    res = subprocess.run(
        [sys.executable, "-c", SPMD_SCRIPT], capture_output=True, text=True, env=env, timeout=600,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert "SPMD_OK" in res.stdout, res.stderr[-2000:]
