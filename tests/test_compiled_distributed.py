"""Compiled (static-shape) engine + distributed HyperCube joins."""
import subprocess
import sys

import numpy as np
import pytest

from repro.core import binary2fj, factor, gj_plan
from repro.core.compiled import count_query
from repro.core.distributed import distributed_join_host, hypercube_shares, partition
from repro.relational.oracle import join_oracle
from repro.relational.relation import Relation
from repro.relational.schema import Atom, Query, clover_query, triangle_query
from tests.conftest import rand_rel


@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("impl", ["jnp", "pallas_interpret"])
def test_compiled_count_triangle(seed, impl):
    rng = np.random.default_rng(seed)
    q = triangle_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 40, 8) for a in q.atoms}
    want = len(join_oracle(q, rels))
    fj = factor(binary2fj(q.atoms, q))
    got, ovf = count_query(fj, rels, [4096] * 4, impl=impl)
    assert not ovf and got == want


def test_compiled_count_gj_plan(rng):
    q = triangle_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 40, 8) for a in q.atoms}
    want = len(join_oracle(q, rels))
    got, ovf = count_query(gj_plan(q, ["x", "y", "z"]), rels, [4096] * 4)
    assert not ovf and got == want


def test_compiled_overflow_detected(rng):
    q = clover_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 60, 5) for a in q.atoms}
    fj = factor(binary2fj(q.atoms, q))
    _, ovf = count_query(fj, rels, [4] * 4)
    assert ovf


def test_compiled_bag_semantics():
    rels = {
        "R": Relation("R", {"x": np.array([1, 1, 1]), "a": np.array([5, 5, 7])}),
        "S": Relation("S", {"x": np.array([1, 1]), "b": np.array([9, 9])}),
    }
    q = Query([Atom("R", ("x", "a")), Atom("S", ("x", "b"))])
    fj = factor(binary2fj(q.atoms, q))
    got, ovf = count_query(fj, rels, [64] * 3)
    assert not ovf and got == 6


def test_hypercube_shares_triangle_is_cube():
    q = triangle_query()
    shares = hypercube_shares(q, {"R": 100, "S": 100, "T": 100}, 8)
    assert sorted(shares.values()) == [2, 2, 2]


def test_partition_covers_every_output(rng):
    q = triangle_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 60, 8) for a in q.atoms}
    want = len(join_oracle(q, rels))
    got = distributed_join_host(q, rels, num_shards=8, agg="count")
    assert got == want


def test_distributed_materialized(rng):
    q = triangle_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 40, 6) for a in q.atoms}
    out = distributed_join_host(q, rels, num_shards=4)
    got = sorted(zip(*(out[v] for v in q.head)))
    want = join_oracle(q, rels)
    assert [tuple(map(int, t)) for t in got] == want


SPMD_SCRIPT = r"""
import numpy as np, jax
from repro.relational.schema import triangle_query
from repro.relational.relation import Relation
from repro.relational.oracle import join_oracle
from repro.core import binary2fj, factor
from repro.core.distributed import spmd_count  # has the shard_map compat alias
rng = np.random.default_rng(0)
q = triangle_query()
rels = {a.alias: Relation(a.alias, {v: rng.integers(0, 12, 120) for v in a.vars}) for a in q.atoms}
want = len(join_oracle(q, rels))
mesh = jax.make_mesh((8,), ("data",))
fj = factor(binary2fj(q.atoms, q))
got = spmd_count(q, rels, fj, [8192] * 4, mesh)
assert got == want, (got, want)
print("SPMD_OK", got)
"""


@pytest.mark.slow
def test_spmd_count_8_devices_subprocess():
    """shard_map + psum on 8 fake CPU devices (subprocess so the fake
    device count never leaks into this test session). Slow: compiles the
    whole executor once per device mesh in a fresh process."""
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=8", "PYTHONPATH": "src"}
    import os

    env = {**os.environ, **env}
    res = subprocess.run(
        [sys.executable, "-c", SPMD_SCRIPT], capture_output=True, text=True, env=env, timeout=600,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert "SPMD_OK" in res.stdout, res.stderr[-2000:]
