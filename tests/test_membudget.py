"""MemoryGovernor unit tests: accounting arithmetic, LRU eviction order,
admission shedding, owner-lifetime release, and budget scoping. The
integration half — the governed caches never exceeding a live budget —
lives in test_chaos.py with the rest of the resilience suite."""
import gc

import numpy as np
import pytest

from repro.core import membudget
from repro.core.membudget import MemoryBudgetError, MemoryGovernor


def test_bookkeeping_without_budget_never_refuses():
    gov = MemoryGovernor()
    gov.account("a", 100)
    gov.account("b", 50)
    assert gov.live_bytes == 150 and gov.peak_bytes == 150
    gov.account("a", 30)  # resize down
    assert gov.live_bytes == 80
    gov.release("b")
    assert gov.live_bytes == 30
    assert gov.evictions == 0 and gov.sheds == 0
    # huge entries are fine: no budget, no enforcement
    gov.account("c", 1 << 60)
    assert gov.peak_bytes == 30 + (1 << 60)


def test_lru_eviction_order_and_callbacks():
    dropped = []
    gov = MemoryGovernor(budget_bytes=100)
    for name, n in (("a", 40), ("b", 40), ("c", 20)):
        gov.account(name, n, evict=lambda name=name: dropped.append(name))
    # "a" is coldest; touching it promotes it, so "b" pays for "d"
    gov.touch("a")
    gov.account("d", 30, evict=lambda: dropped.append("d"))
    assert dropped == ["b"]
    assert gov.live_bytes == 40 + 20 + 30
    assert gov.evictions == 1
    # the evicted token is really gone: accounting it again is a fresh entry
    gov.account("b", 10, evict=lambda: dropped.append("b2"))
    assert gov.live_bytes == 100


def test_shed_leaves_state_untouched():
    gov = MemoryGovernor(budget_bytes=100)
    gov.account("a", 60, evict=lambda: None)
    with pytest.raises(MemoryBudgetError) as ei:
        gov.account("whale", 200)
    assert ei.value.budget == 100
    assert gov.sheds == 1
    assert "whale" not in gov._entries
    # the resident entry was evicted trying to make room — that is the
    # documented cost of a shed — but the governed total stays consistent
    assert gov.live_bytes <= 100


def test_growing_an_entry_never_evicts_itself():
    gov = MemoryGovernor(budget_bytes=100)
    gov.account("me", 60, evict=lambda: pytest.fail("self-eviction"))
    # growth that fits once cold entries go: "other" is evicted, not "me"
    gone = []
    gov.account("other", 30, evict=lambda: gone.append("other"))
    gov.account("me", 90)
    assert gone == ["other"]
    assert gov.live_bytes == 90
    # growth that cannot fit even alone sheds, and the OLD size survives
    with pytest.raises(MemoryBudgetError):
        gov.account("me", 150)
    assert gov._entries["me"][0] == 90 and gov.live_bytes == 90


def test_owner_gc_releases_token():
    gov = MemoryGovernor()

    class Owner:
        pass

    o = Owner()
    gov.account("t", 77, owner=o)
    assert gov.live_bytes == 77
    del o
    gc.collect()
    assert gov.live_bytes == 0 and "t" not in gov._entries


def test_release_detaches_owner_finalizer():
    gov = MemoryGovernor()

    class Owner:
        pass

    o = Owner()
    gov.account("t", 10, owner=o)
    gov.release("t")
    gov.account("t2", 5)
    del o
    gc.collect()  # the dead finalizer must not touch anything
    assert gov.live_bytes == 5


def test_set_budget_shrink_evicts_coldest_first():
    gone = []
    gov = MemoryGovernor()
    for name in ("a", "b", "c"):
        gov.account(name, 40, evict=lambda name=name: gone.append(name))
    gov.set_budget(50)
    assert gone == ["a", "b"]
    assert gov.live_bytes == 40 and gov.budget == 50


def test_budget_context_restores_previous():
    gov = membudget.GOVERNOR
    old = gov.budget
    with membudget.budget(1 << 30) as g:
        assert g is gov and gov.budget == 1 << 30
    assert gov.budget == old


def test_nbytes_walks_nested_structures():
    a = np.zeros(10, np.int32)  # 40 bytes
    assert membudget._nbytes(a) == 40
    assert membudget._nbytes({"x": a, "y": [a, (a, a, None)]}) == 160
    assert membudget._nbytes(None) == 0
    assert membudget._nbytes(3) == 0  # scalars carry no .nbytes
