"""Engine correctness vs the brute-force oracle: all three algorithms,
bag semantics, bushy plans, edge cases, count aggregation."""
import numpy as np
import pytest

from repro.core import (
    BinaryPlan,
    binary2fj,
    binary_join,
    factor,
    free_join,
    generic_join,
    linear,
    optimize,
    to_sorted_tuples,
)
from repro.core.tuple_engine import execute_tuples
from repro.relational.oracle import join_oracle
from repro.relational.relation import Relation
from repro.relational.schema import Atom, Query, clover_query, triangle_query
from tests.conftest import rand_rel

ENGINES = [free_join, binary_join, generic_join]


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("engine", ENGINES)
def test_triangle_matches_oracle(engine, seed):
    rng = np.random.default_rng(seed)
    q = triangle_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 60, 10) for a in q.atoms}
    want = join_oracle(q, rels)
    got = to_sorted_tuples(engine(q, rels), q.head)
    assert got == want
    assert engine(q, rels, agg="count") == len(want)


@pytest.mark.parametrize("engine", ENGINES)
def test_clover_skewed_instance(engine):
    # the paper's Fig. 3 adversarial instance
    n = 30
    ar = np.arange(n, dtype=np.int64)
    rels = {
        "R": Relation(
            "R", {"x": np.r_[0, np.full(n, 1), np.full(n, 2)], "a": np.r_[0, ar, ar + n]}
        ),
        "S": Relation(
            "S", {"x": np.r_[0, np.full(n, 2), np.full(n, 3)], "b": np.r_[0, ar, ar + n]}
        ),
        "T": Relation(
            "T", {"x": np.r_[0, np.full(n, 3), np.full(n, 1)], "c": np.r_[0, ar, ar + n]}
        ),
    }
    q = clover_query()
    got = to_sorted_tuples(engine(q, rels), q.head)
    assert got == [(0, 0, 0, 0)]


@pytest.mark.parametrize("engine", ENGINES)
def test_bag_semantics_duplicates(engine):
    rels = {
        "R": Relation("R", {"x": np.array([1, 1, 1]), "a": np.array([5, 5, 7])}),
        "S": Relation("S", {"x": np.array([1, 1]), "b": np.array([9, 9])}),
    }
    q = Query([Atom("R", ("x", "a")), Atom("S", ("x", "b"))])
    want = join_oracle(q, rels)
    assert len(want) == 6
    assert to_sorted_tuples(engine(q, rels), q.head) == want


def test_bushy_plan_materialization(rng):
    q = Query(
        [Atom("R", ("x", "y")), Atom("S", ("y", "z")), Atom("T", ("z", "u")), Atom("U", ("u", "w"))]
    )
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 80, 8) for a in q.atoms}
    tree = BinaryPlan(BinaryPlan(q.atoms[0], q.atoms[1]), BinaryPlan(q.atoms[2], q.atoms[3]))
    want = join_oracle(q, rels)
    for engine in (free_join, binary_join):
        assert to_sorted_tuples(engine(q, rels, tree), q.head) == want


def test_cross_product():
    rels = {"R": Relation("R", {"x": np.arange(4)}), "S": Relation("S", {"y": np.arange(3)})}
    q = Query([Atom("R", ("x",)), Atom("S", ("y",))])
    got = to_sorted_tuples(free_join(q, rels, linear(q.atoms)), q.head)
    assert got == join_oracle(q, rels)


def test_empty_relation():
    rels = {
        "R": Relation("R", {"x": np.arange(5), "y": np.arange(5)}),
        "S": Relation("S", {"y": np.array([], np.int64), "z": np.array([], np.int64)}),
    }
    q = Query([Atom("R", ("x", "y")), Atom("S", ("y", "z"))])
    for engine in ENGINES:
        assert to_sorted_tuples(engine(q, rels), q.head) == []


def test_self_join_aliases(rng):
    E = rand_rel(rng, "E", ("x", "y"), 50, 8)
    q = Query([Atom("E", ("x", "y"), "E1"), Atom("E", ("y", "z"), "E2")])
    rels = {"E1": E, "E2": E.rename({"x": "y", "y": "z"})}
    want = join_oracle(q, rels)
    for engine in ENGINES:
        assert to_sorted_tuples(engine(q, rels), q.head) == want


def test_tuple_engine_matches_full_batch(rng):
    q = triangle_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 40, 8) for a in q.atoms}
    fj = factor(binary2fj(q.atoms, q))
    want = join_oracle(q, rels)
    for bs in (1, 10, 1000):
        assert sorted(execute_tuples(fj, rels, batch_size=bs)) == want


@pytest.mark.parametrize("mode", ["colt", "slt", "simple"])
def test_trie_modes_agree(rng, mode):
    q = triangle_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 60, 9) for a in q.atoms}
    want = join_oracle(q, rels)
    got = to_sorted_tuples(free_join(q, rels, mode=mode), q.head)
    assert got == want


def test_optimizer_good_and_bad_same_result(rng):
    q = Query(
        [Atom("A", ("x", "y")), Atom("B", ("y", "z")), Atom("C", ("z", "w")), Atom("D", ("w", "x"))]
    )
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 50, 6) for a in q.atoms}
    want = join_oracle(q, rels)
    for bad in (False, True):
        tree = optimize(q, rels, bad=bad)
        for engine in (free_join, binary_join):
            assert to_sorted_tuples(engine(q, rels, tree), q.head) == want


def test_factorized_count_equals_materialized(rng):
    q = clover_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 100, 5) for a in q.atoms}
    c = free_join(q, rels, agg="count")
    bound, mult = free_join(q, rels)
    assert c == int(mult.sum()) == len(join_oracle(q, rels))


def test_execute_trie_reuse_and_build_ns_snapshot(rng):
    """Repeat execute() calls may share one Colt dict (same plan, same
    relations): results must match and stats.build_ns must account only
    the forcing done by each call, not the tries' lifetime totals."""
    from repro.core.colt import Colt
    from repro.core.engine import ExecStats, execute

    q = triangle_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 50, 8) for a in q.atoms}
    fj = factor(binary2fj(q.atoms, q))
    want = execute(fj, rels, agg="count")
    parts = fj.partitions()
    tries = {a: Colt(rels[a], parts[a], mode="colt") for a in parts}
    st = ExecStats()
    assert execute(fj, rels, agg="count", tries=tries, stats=st) == want
    first_build = st.build_ns
    assert first_build > 0  # the first call forced the probed levels
    assert execute(fj, rels, agg="count", tries=tries, stats=st) == want
    # second call reuses the forced levels: (almost) no new build time
    assert st.build_ns - first_build < first_build
