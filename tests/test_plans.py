"""Plan-layer unit tests: binary2fj, factor, validity (paper Figs 9-10)."""
from repro.core.plan import (
    BinaryPlan,
    FreeJoinPlan,
    Subatom,
    binary2fj,
    factor,
    gj_plan,
    var_order_from_fj,
)
from repro.relational.schema import Atom, Query, clover_query, triangle_query


def test_binary2fj_clover_matches_paper_eq2():
    q = clover_query()
    fj = binary2fj(q.atoms, q)
    assert str(fj) == "[[R(x,a), S(x)], [S(b), T(x)], [T(c)]]"


def test_factor_clover_matches_paper_optimized_plan():
    q = clover_query()
    fj = factor(binary2fj(q.atoms, q))
    assert str(fj) == "[[R(x,a), S(x), T(x)], [S(b)], [T(c)]]"


def test_binary2fj_chain_matches_paper_example_4_1():
    q = Query(
        [Atom("R", ("x", "y")), Atom("S", ("y", "z")), Atom("T", ("z", "u")), Atom("W", ("u", "v"))]
    )
    fj = binary2fj(q.atoms, q)
    assert str(fj) == "[[R(x,y), S(y)], [S(z), T(z)], [T(u), W(u)], [W(v)]]"


def test_gj_plan_is_all_covers():
    q = clover_query()
    plan = gj_plan(q, ["x", "a", "b", "c"])
    assert str(plan) == "[[R(x), S(x), T(x)], [R(a)], [S(b)], [T(c)]]"
    plan.validate()


def test_invalid_plan_example_3_9_rejected():
    q = clover_query()
    plan = FreeJoinPlan(
        q, [[Subatom("R", ("x", "a")), Subatom("S", ("x", "b")), Subatom("T", ("x", "c"))]]
    )
    # single node containing everything: S(x,b) needs b which is not fresh-covered
    # by any single subatom... actually R(x,a) doesn't contain b,c -> no cover
    assert not plan.is_valid()


def test_partitioning_violation_rejected():
    q = clover_query()
    plan = FreeJoinPlan(
        q, [[Subatom("R", ("x",))], [Subatom("S", ("x", "b"))], [Subatom("T", ("x", "c"))]]
    )
    assert not plan.is_valid()  # R(a) missing


def test_factored_plan_always_valid_random_chains(rng):
    import itertools

    vars_ = ["a", "b", "c", "d", "e", "f"]
    for m in (3, 4, 5):
        atoms = [Atom(f"R{i}", (vars_[i], vars_[i + 1])) for i in range(m)]
        q = Query(atoms)
        for perm in itertools.islice(itertools.permutations(atoms), 8):
            fj = factor(binary2fj(list(perm), q))
            fj.validate()


def test_bushy_decompose():
    q = Query(
        [Atom("R", ("x", "y")), Atom("S", ("y", "z")), Atom("T", ("z", "u")), Atom("U", ("u", "w"))]
    )
    tree = BinaryPlan(BinaryPlan(q.atoms[0], q.atoms[1]), BinaryPlan(q.atoms[2], q.atoms[3]))
    stages = tree.decompose()
    assert len(stages) == 2
    assert stages[-1][0] == "__root"
    assert isinstance(stages[0][1][0], Atom)


def test_var_order_extension():
    q = triangle_query()
    fj = factor(binary2fj(q.atoms, q))
    order = var_order_from_fj(fj)
    assert sorted(order) == sorted(q.variables)
