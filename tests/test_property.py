"""Hypothesis property tests on the system's invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import binary_join, free_join, generic_join, to_sorted_tuples
from repro.core.plan import binary2fj, factor
from repro.relational.npkit import HashTable, group_by
from repro.relational.oracle import join_oracle
from repro.relational.relation import Relation
from repro.relational.schema import Atom, Query

VARS = ["u", "v", "w", "x", "y"]


@st.composite
def random_query(draw):
    """2-4 atoms over a small shared var pool, connected-ish."""
    m = draw(st.integers(2, 4))
    atoms = []
    used: list[str] = []
    for i in range(m):
        pool = used if used and draw(st.booleans()) else VARS
        k = draw(st.integers(1, min(3, len(pool))))
        vs = draw(
            st.lists(st.sampled_from(pool), min_size=k, max_size=k, unique=True)
        )
        # make sure atoms overlap so the query is connected
        if used and not (set(vs) & set(used)):
            vs[0] = used[0]
        atoms.append(Atom(f"R{i}", tuple(dict.fromkeys(vs))))
        used.extend(v for v in vs if v not in used)
    return Query(atoms)


@st.composite
def instance(draw, q):
    rels = {}
    for a in q.atoms:
        n = draw(st.integers(0, 25))
        cols = {
            v: np.array(draw(st.lists(st.integers(0, 5), min_size=n, max_size=n)), np.int64)
            for v in a.vars
        }
        rels[a.alias] = Relation(a.alias, cols)
    return rels


@given(data=st.data())
@settings(max_examples=40, deadline=None)
def test_all_engines_match_oracle(data):
    q = data.draw(random_query())
    rels = data.draw(instance(q))
    want = join_oracle(q, rels)
    for engine in (free_join, binary_join, generic_join):
        got = to_sorted_tuples(engine(q, rels), q.head)
        assert got == want
        assert engine(q, rels, agg="count") == len(want)


@given(data=st.data())
@settings(max_examples=40, deadline=None)
def test_factor_preserves_validity_and_semantics(data):
    q = data.draw(random_query())
    rels = data.draw(instance(q))
    fj = binary2fj(q.atoms, q)
    ff = factor(fj)
    ff.validate()
    from repro.core import engine

    a = engine.execute(fj, rels)
    b = engine.execute(ff, rels)
    from repro.core.api import to_sorted_tuples as ts

    assert ts(a, q.head) == ts(b, q.head)


@given(
    keys=st.lists(st.tuples(st.integers(-2**31, 2**31 - 1), st.integers(-2**31, 2**31 - 1)),
                  min_size=0, max_size=200, unique=True),
    queries=st.lists(st.tuples(st.integers(-2**31, 2**31 - 1), st.integers(-2**31, 2**31 - 1)),
                     min_size=0, max_size=100),
)
@settings(max_examples=50, deadline=None)
def test_hashtable_probe_total(keys, queries):
    cols = (
        [np.array([k[i] for k in keys], np.int64) for i in range(2)]
        if keys
        else [np.zeros(0, np.int64)] * 2
    )
    t = HashTable(cols)
    qcols = (
        [np.array([k[i] for k in queries], np.int64) for i in range(2)]
        if queries
        else [np.zeros(0, np.int64)] * 2
    )
    res = t.probe(qcols)
    lookup = {k: i for i, k in enumerate(keys)}
    for j, qk in enumerate(queries):
        assert res[j] == lookup.get(qk, -1)


@given(
    rows=st.lists(st.tuples(st.integers(0, 6), st.integers(0, 6)), min_size=0, max_size=100)
)
@settings(max_examples=50, deadline=None)
def test_group_by_partitions(rows):
    cols = (
        [np.array([r[i] for r in rows], np.int64) for i in range(2)]
        if rows
        else [np.zeros(0, np.int64)] * 2
    )
    uniq, gid, order, offsets = group_by(cols)
    n = len(rows)
    assert len(order) == n and offsets[-1] == n
    # every row's group key matches the unique key of its group
    for i in range(n):
        g = gid[i]
        assert (cols[0][i], cols[1][i]) == (uniq[0][g], uniq[1][g])
    # offsets partition the sorted order into contiguous equal-key runs
    for g in range(len(uniq[0])):
        seg = order[offsets[g]:offsets[g + 1]]
        assert all(gid[s] == g for s in seg)
