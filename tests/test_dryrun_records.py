"""Deliverable (e) integrity: every (arch x shape x mesh) dry-run record
exists and is ok (or a documented long_500k structural skip)."""
import glob
import json
import os

import pytest

from repro.configs import ARCHS, SHAPES, get_arch

DIR = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "benchmarks", "results", "dryrun")


@pytest.mark.skipif(not os.path.isdir(DIR), reason="dry-run not yet executed")
@pytest.mark.parametrize("pod", ["pod1", "pod2"])
def test_all_cells_recorded_and_green(pod):
    for arch in ARCHS:
        spec = get_arch(arch)
        for shape in SHAPES:
            path = os.path.join(DIR, f"{arch}_{shape}_{pod}.json")
            assert os.path.exists(path), f"missing dry-run record {path}"
            rec = json.load(open(path))
            if spec.shape_supported(shape):
                assert rec["status"] == "ok", (arch, shape, pod, rec.get("error"))
                assert rec.get("flops") or rec["raw"]["flops"]
            else:
                assert rec["status"] == "skipped"


OPT_DIR = DIR + "_opt"


@pytest.mark.skipif(not os.path.isdir(DIR), reason="dry-run not yet executed")
def test_memory_fits_hbm_at_production_config():
    """Train cells at the mb=8 production config must fit 16 GB/chip for
    the <100B archs. The >=140B MoE archs keep optimizer state sharded
    under HBM (args < 16 GB) but need deeper grad accumulation or the
    512-chip mesh for activation fit at 256 chips — recorded in
    EXPERIMENTS.md §Dry-run, asserted as state-fits here."""
    hbm = 16e9
    use = OPT_DIR if os.path.isdir(OPT_DIR) else DIR
    for f in glob.glob(os.path.join(use, "*train_4k_pod1.json")):
        rec = json.load(open(f))
        if rec["status"] != "ok":
            continue
        mem = rec.get("memory_mb8") or rec["memory"]
        args = rec["memory"].get("argument_size_in_bytes") or 0
        temps = mem.get("temp_size_in_bytes") or 0
        if rec["params_total"] < 100e9:
            assert args + temps < hbm, (rec["arch"], args / 1e9, temps / 1e9)
        else:
            assert args < hbm, (rec["arch"], args / 1e9)
