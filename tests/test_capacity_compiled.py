"""Capacity planner + adaptive compiled execution + frontier compaction."""
import jax
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import compiled_free_join, free_join, optimize, to_sorted_tuples
from repro.core.capacity import CapacityPlan, agm_bound, plan_capacities
from repro.core.compiled import AdaptiveExecutor, make_executor, relations_to_cols
from repro.core.optimizer import estimate_prefixes
from repro.core.plan import binary2fj, factor
from repro.kernels import ops, ref
from repro.relational.oracle import join_oracle
from repro.relational.relation import Relation
from repro.relational.schema import Atom, Query, triangle_query
from tests.conftest import rand_rel

IMPLS = ["jnp", "pallas_interpret", "pallas"]


def _skip_if_unrunnable(impl):
    if impl == "pallas" and jax.default_backend() == "cpu":
        pytest.skip("compiled Pallas needs a TPU/GPU backend")


def four_cycle_query() -> Query:
    return Query(
        [Atom("R", ("x", "y")), Atom("S", ("y", "z")), Atom("T", ("z", "w")), Atom("U", ("w", "x"))]
    )


def path_query(m: int) -> Query:
    vs = [f"v{i}" for i in range(m + 1)]
    return Query([Atom(f"R{i}", (vs[i], vs[i + 1])) for i in range(m)])


def star_query(m: int) -> Query:
    return Query([Atom(f"R{i}", ("h", f"s{i}")) for i in range(m)])


# ---- end-to-end parity: no manual capacities anywhere --------------------


@pytest.mark.parametrize("seed", range(3))
@pytest.mark.parametrize("make_q", [lambda: path_query(3), lambda: star_query(3)])
def test_compiled_eager_parity_acyclic(seed, make_q):
    rng = np.random.default_rng(seed)
    q = make_q()
    assert q.is_acyclic()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 50 + 10 * seed, 7) for a in q.atoms}
    want = free_join(q, rels, agg="count")
    info = {}
    got = compiled_free_join(q, rels, agg="count", info=info)
    assert got == want
    assert info["retries"] == 0, "planner capacities should not overflow here"


@pytest.mark.parametrize("seed", range(3))
@pytest.mark.parametrize("make_q", [triangle_query, four_cycle_query])
def test_compiled_eager_parity_cyclic(seed, make_q):
    rng = np.random.default_rng(seed)
    q = make_q()
    assert not q.is_acyclic()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 60, 9) for a in q.atoms}
    want = free_join(q, rels, agg="count")
    got = compiled_free_join(q, rels, agg="count")
    assert got == want


def test_compiled_materialization_matches_oracle(rng):
    q = triangle_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 50, 7) for a in q.atoms}
    bound, mult = compiled_free_join(q, rels, agg=None)
    assert to_sorted_tuples((bound, mult), q.head) == join_oracle(q, rels)


@pytest.mark.parametrize("empty_alias", ["R", "S", "T"])
def test_compiled_empty_relation(rng, empty_alias):
    # zero-row relations run through the executor natively: an empty trie's
    # every frontier expansion yields zero live lanes (no host-side gate)
    q = triangle_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 40, 8) for a in q.atoms}
    vars_ = q.atom(empty_alias).vars
    rels[empty_alias] = Relation(empty_alias, {v: np.zeros(0, np.int64) for v in vars_})
    assert free_join(q, rels, agg="count", compiled=True) == 0
    bound, mult = compiled_free_join(q, rels, agg=None)
    assert to_sorted_tuples((bound, mult), q.head) == []


def test_compiled_all_relations_empty(rng):
    q = triangle_query()
    rels = {
        a.alias: Relation(a.alias, {v: np.zeros(0, np.int64) for v in a.vars})
        for a in q.atoms
    }
    assert free_join(q, rels, agg="count", compiled=True) == 0
    bound, mult = free_join(q, rels, agg=None, compiled=True)
    assert to_sorted_tuples((bound, mult), q.head) == []


def test_compiled_bag_materialization():
    rels = {
        "R": Relation("R", {"x": np.array([1, 1, 1]), "a": np.array([5, 5, 7])}),
        "S": Relation("S", {"x": np.array([1, 1]), "b": np.array([9, 9])}),
    }
    q = Query([Atom("R", ("x", "a")), Atom("S", ("x", "b"))])
    bound, mult = compiled_free_join(q, rels, agg=None)
    assert to_sorted_tuples((bound, mult), q.head) == join_oracle(q, rels)
    assert int(np.sum(mult)) == 6


# ---- adaptive overflow recovery ------------------------------------------


def test_overflow_retry_converges_from_undersized_plan(rng):
    q = triangle_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 40, 6) for a in q.atoms}
    want = free_join(q, rels, agg="count")
    fj = factor(binary2fj(q.atoms, q))
    n = len(plan_capacities(fj, rels).capacities)
    # undersized by ~2-4x: a couple of doublings (= executor recompiles) fix it
    tiny = CapacityPlan(capacities=(64,) * n, compact_to=(None,) * n)
    ex = AdaptiveExecutor(fj, tiny, agg="count")
    got = ex.run_relations(rels)
    assert got == want
    assert ex.retries > 0, "a forced initial overflow must actually retry"
    assert max(ex.cap_plan.capacities) > 64
    # steady state: the grown plan is cached, a second call never re-runs
    compiles = ex.compiles
    retries = ex.retries
    assert ex.run_relations(rels) == want
    assert ex.retries == retries and ex.compiles == compiles


def test_overflow_retry_grows_only_offending_node(rng):
    q = triangle_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 40, 6) for a in q.atoms}
    fj = factor(binary2fj(q.atoms, q))
    good = plan_capacities(fj, rels)
    # undersize only the last node; earlier capacities must stay untouched
    caps = list(good.capacities)
    caps[-1] = 128
    ex = AdaptiveExecutor(
        fj, CapacityPlan(capacities=tuple(caps), compact_to=good.compact_to), agg="count"
    )
    assert ex.run_relations(rels) == free_join(q, rels, agg="count")
    assert ex.cap_plan.capacities[:-1] == good.capacities[:-1]
    assert ex.cap_plan.capacities[-1] > 128
    # the executor reported the node's exact required total, so the runner
    # jumps straight there: one retry, not a geometric doubling ladder
    assert ex.retries == 1


# ---- compaction ----------------------------------------------------------


@pytest.mark.parametrize("impl", IMPLS)
def test_compact_matches_reference(impl, rng):
    _skip_if_unrunnable(impl)
    for n, cap in [(1, 1024), (1000, 1024), (4096, 2048)]:
        valid = jnp.asarray(rng.random(n) < 0.3)
        ws, wl = ref.compact_ref(valid, cap)
        gs, gl = ops.compact_indices(valid, cap, impl=impl)
        np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))
        assert int(gl) == int(wl)


@pytest.mark.parametrize("impl", IMPLS)
def test_compact_idempotent(impl, rng):
    """compact∘compact = compact: recompacting a compacted frontier is the
    identity on the live prefix."""
    _skip_if_unrunnable(impl)
    n, cap = 3000, 2048
    valid = jnp.asarray(rng.random(n) < 0.2)
    payload = jnp.asarray(rng.integers(0, 10**6, n).astype(np.int32))
    src1, live1 = ops.compact_indices(valid, cap, impl=impl)
    out1 = jnp.where(src1 >= 0, payload[jnp.clip(src1, 0, n - 1)], -1)
    valid1 = jnp.arange(cap) < live1
    src2, live2 = ops.compact_indices(valid1, cap, impl=impl)
    out2 = jnp.where(src2 >= 0, out1[jnp.clip(src2, 0, cap - 1)], -1)
    assert int(live2) == int(live1)
    np.testing.assert_array_equal(np.asarray(out2), np.asarray(out1))


def test_executor_with_forced_compaction_matches(rng):
    q = triangle_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 120, 40) for a in q.atoms}
    want = free_join(q, rels, agg="count")
    fj = factor(binary2fj(q.atoms, q))
    caps = [4096] * 2
    cols = relations_to_cols(fj, rels)
    plain = jax.jit(make_executor(fj, caps))(cols)
    squeezed = jax.jit(make_executor(fj, caps, compact_to=[1024, None]))(cols)
    assert int(plain[0]) == want == int(squeezed[0])
    # executors report *required totals* per node, not overflow bits
    assert (np.asarray(squeezed[1]) <= np.array(caps)).all()
    assert np.asarray(squeezed[2])[0] <= 1024


def test_midnode_compaction_between_probes(rng):
    """Factored star plan: node 0 is [R(x,y), S(y), T(y)]. Compacting right
    after the selective S probe must not change the count, and the planner
    must actually schedule a mid-node compact point on low selectivity."""
    q = Query([Atom("R", ("x", "y")), Atom("S", ("y", "a")), Atom("T", ("y", "b"))])
    n, dom = 400, 40
    y_live = rng.choice(dom, 3, replace=False)  # S kills ~92% of lanes
    rels = {
        "R": rand_rel(rng, "R", ("x", "y"), n, dom),
        "S": Relation("S", {"y": y_live[rng.integers(0, 3, 6)], "a": rng.integers(0, dom, 6)}),
        "T": rand_rel(rng, "T", ("y", "b"), n // 4, dom),
    }
    want = free_join(q, rels, agg="count")
    fj = factor(binary2fj(q.atoms, q))
    assert [sa.alias for sa in fj.nodes[0]] == ["R", "S", "T"]
    cp = plan_capacities(fj, rels, block=128)  # tiny data: sub-1024 blocks
    assert cp.compact_to[0] is not None and cp.compact_probe[0] == 1
    cols = relations_to_cols(fj, rels)
    for cpr in [None, cp.compact_probe]:  # after-node vs mid-node
        out = jax.jit(make_executor(fj, cp.capacities, compact_to=cp.compact_to,
                                    compact_probe=cpr))(cols)
        assert int(out[0]) == want
        assert (np.asarray(out[1]) <= np.array(cp.capacities)).all()
        assert np.asarray(out[2])[0] <= cp.compact_to[0]
    ex = AdaptiveExecutor(fj, cp, agg="count")
    assert ex.run_relations(rels) == want


def test_compaction_overflow_detected_and_recovered(rng):
    q = triangle_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 60, 10) for a in q.atoms}
    fj = factor(binary2fj(q.atoms, q))
    # ample expand buffer, absurdly small compaction target -> compact overflow
    cp = CapacityPlan(capacities=(1024, 1024), compact_to=(16, None))
    cols = relations_to_cols(fj, rels)
    out = jax.jit(make_executor(fj, cp.capacities, compact_to=cp.compact_to))(cols)
    assert np.asarray(out[2])[0] > 16, "compaction overflow must be reported as the live need"
    ex = AdaptiveExecutor(fj, cp, agg="count")
    assert ex.run_relations(rels) == free_join(q, rels, agg="count")
    assert ex.retries > 0


# ---- planner -------------------------------------------------------------


def test_agm_bound_triangle_exact():
    edges = {"R": ("x", "y"), "S": ("y", "z"), "T": ("z", "x")}
    n = 500.0
    assert agm_bound(edges, {a: n for a in edges}) == pytest.approx(n**1.5, rel=1e-6)


def test_capacity_plan_block_aligned_and_agm_capped(rng):
    q = triangle_query()
    # dense small domain: estimates explode past the AGM bound
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 400, 4) for a in q.atoms}
    cp = plan_capacities(factor(binary2fj(q.atoms, q)), rels, block=1024)
    assert all(c % 1024 == 0 for c in cp.capacities)
    for cap, bound in zip(cp.capacities, cp.agm):
        assert cap <= max(1024, int(np.ceil(bound / 1024)) * 1024)
    ests = cp.estimates
    assert len(ests) == len(cp.capacities)
    assert all(e.after <= e.expand for e in ests)


def test_estimates_track_truth_within_order_of_magnitude(rng):
    q = triangle_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 200, 20) for a in q.atoms}
    fj = factor(binary2fj(q.atoms, q))
    truth = free_join(q, rels, agg="count")
    est = estimate_prefixes(fj, rels)[-1].after
    assert truth / 50 <= est <= truth * 50


# ---- shared planning pass -------------------------------------------------


def test_planning_pass_host_work(rng, monkeypatch):
    """Greedy planning (optimize_level=0) computes one Stats cache and one
    StaticSchedule per query: exactly one np.unique per referenced column (6
    for the triangle) and one _static_schedule call across optimize ->
    plan_capacities -> estimate_prefixes -> make_executor. The enumerating
    default additionally schedules each device-costed finalist on the COLD
    call (bounded by the optimizer's `keep`), reuses the same Stats cache
    (zero extra np.unique), and a warm repeat — pinned choice, cached runner
    — does zero planning host work of either kind."""
    import repro.core.compiled as compiled_mod
    from repro.core import ExecOptions

    q = triangle_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 40, 8) for a in q.atoms}
    want = free_join(q, rels, agg="count")

    uniq, sched = [0], [0]
    orig_unique, orig_sched = np.unique, compiled_mod._static_schedule
    monkeypatch.setattr(
        np, "unique", lambda *a, **k: (uniq.__setitem__(0, uniq[0] + 1), orig_unique(*a, **k))[1]
    )
    monkeypatch.setattr(
        compiled_mod,
        "_static_schedule",
        lambda p: (sched.__setitem__(0, sched[0] + 1), orig_sched(p))[1],
    )
    greedy = ExecOptions(optimize_level=0)
    assert compiled_free_join(q, rels, agg="count", options=greedy) == want
    assert uniq[0] == 6, f"one np.unique per column, got {uniq[0]}"
    assert sched[0] == 1, f"one schedule computation per query, got {sched[0]}"

    # cold enumerating call: per-finalist costing, same Stats cache
    assert compiled_free_join(q, rels, agg="count") == want
    cold_uniq, cold_sched = uniq[0], sched[0]
    assert cold_uniq == 6, f"Stats cache shared across levels, got {cold_uniq}"
    assert cold_sched <= 1 + 2 * 3 + 2, f"finalist costing unbounded: {cold_sched}"

    # warm repeat: choice pinned, runner cached — zero host planning
    assert compiled_free_join(q, rels, agg="count") == want
    assert (uniq[0], sched[0]) == (cold_uniq, cold_sched), "warm call re-planned"


def test_capacity_plan_carries_schedule(rng):
    q = triangle_query()
    rels = {a.alias: rand_rel(rng, a.alias, a.vars, 40, 8) for a in q.atoms}
    fj = factor(binary2fj(q.atoms, q))
    cp = plan_capacities(fj, rels)
    assert cp.schedule is not None and len(cp.schedule) == len(cp.capacities)
    ex = AdaptiveExecutor(fj, cp, agg="count")
    assert ex.schedule is cp.schedule  # reused, not recomputed
    # grow() / grow_to() keep the schedule on the derived plans
    assert cp.grow(0).schedule is cp.schedule
    assert cp.grow_to(0, 10**6).schedule is cp.schedule


# ---- optimizer degenerate case (regression) ------------------------------


def test_optimize_bad_single_atom_returns_atom(rng):
    q = Query([Atom("R", ("x", "y"))])
    rels = {"R": rand_rel(rng, "R", ("x", "y"), 25, 5)}
    tree = optimize(q, rels, bad=True)
    assert isinstance(tree, Atom) and tree.alias == "R"
    assert free_join(q, rels, tree, agg="count") == 25
    assert free_join(q, rels, optimize(q, rels), agg="count") == 25
    assert compiled_free_join(q, rels, agg="count") == 25
