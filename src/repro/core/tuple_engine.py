"""Tuple-at-a-time Free Join (Fig. 7) with optional batched probing
(Fig. 13). This is the paper's literal execution model — recursive, one
tuple (or one batch of `batch_size` tuples) per iteration — kept for the
vectorization ablation (Fig. 18) and as a semantic cross-check of the
full-batch engine. It shares the Colt structures; probes go through the
same batched `probe` with small batches.
"""
from __future__ import annotations

import numpy as np

from repro.core.colt import Colt
from repro.core.plan import FreeJoinPlan


def execute_tuples(
    plan: FreeJoinPlan,
    relations,
    *,
    mode: str | dict = "colt",
    batch_size: int = 1000,
    dynamic_cover: bool = True,
):
    """Returns the list of output tuples ordered by plan.query.head."""
    plan.validate()
    parts = plan.partitions()
    modes = mode if isinstance(mode, dict) else {a: mode for a in parts}
    tries = {
        alias: Colt(relations[alias], parts[alias], mode=modes.get(alias, "colt"), filtered=False)
        for alias in parts
    }
    head = plan.query.head
    out: list[tuple] = []

    # state: per-alias (depth, gid); bound: var -> value
    def join(k: int, bound: dict, state: dict):
        if k == len(plan.nodes):
            # bag semantics: multiply leftover leaf multiplicities
            m = 1
            for alias, (d, g) in state.items():
                t = tries[alias]
                if d == t.L and g is not None:
                    m *= int(t.leaf_counts(np.array([g]))[0])
            row = tuple(bound[v] for v in head)
            out.extend([row] * m)
            return
        subs = [sa for sa in plan.nodes[k] if sa.vars]
        if not subs:
            join(k + 1, bound, state)
            return
        covers = [sa for sa in plan.covers(k) if sa.vars and any(sa is s for s in subs)]
        cover = covers[0]
        if dynamic_cover and len(covers) > 1:
            cover = min(
                covers,
                key=lambda sa: tries[sa.alias].key_count_estimate(state[sa.alias][0]),
            )
        probes = [sa for sa in subs if sa is not cover]
        t = tries[cover.alias]
        d, g = state[cover.alias]
        fr, cols, new_gids = t.iter_expand(d, np.array([g if g is not None else 0]))
        n = len(fr)
        # iterate in batches of batch_size (Fig. 13)
        for lo in range(0, n, batch_size):
            hi = min(lo + batch_size, n)
            idx = np.arange(lo, hi)
            tup_cols = {v: c[idx] for v, c in zip(cover.vars, cols)}
            ng = new_gids[idx] if new_gids is not None else None
            alive = np.ones(hi - lo, dtype=bool)
            # semijoin-filter vars the cover re-binds (see engine.py)
            for v in cover.vars:
                if v in bound:
                    alive &= tup_cols[v] == bound[v]
            probe_results: dict[str, np.ndarray] = {}
            for sa in probes:
                pt = tries[sa.alias]
                pd, pg = state[sa.alias]
                gids = np.full(hi - lo, pg if pg is not None else 0, dtype=np.int64)
                keys = [
                    tup_cols[v] if v in tup_cols else np.full(hi - lo, bound[v], dtype=np.int64)
                    for v in sa.vars
                ]
                res = pt.probe(pd, gids, keys)
                alive &= res >= 0
                probe_results[sa.alias] = res
            for j in range(hi - lo):
                if not alive[j]:
                    continue
                b2 = dict(bound)
                for v in cover.vars:
                    b2[v] = int(tup_cols[v][j])
                s2 = dict(state)
                cd = d + 1
                s2[cover.alias] = (cd, int(ng[j]) if ng is not None else None)
                for sa in probes:
                    pd, _ = state[sa.alias]
                    s2[sa.alias] = (pd + 1, int(probe_results[sa.alias][j]))
                join(k + 1, b2, s2)

    state0 = {alias: (0, 0) for alias in parts}
    join(0, {}, state0)
    return out
