"""Static-shape Free Join: the jit/shard_map-able TPU path.

The eager engine (engine.py) is the paper-faithful reproduction; this module
re-expresses the same plan execution with fully static shapes so it lowers
under jit on a device mesh. Since PR 5 the compiled path is split into two
programs with an explicit contract between them:

* The BUILD program (build_trie / StaticTrie) turns a relation's columns
  into a column-oriented lazy trie: one sort over the consumed level vars +
  boundary flags + segment sums — all arrays keep the base relation's
  static length N (group counts are dynamic *values*, never dynamic
  *shapes*). COLT's "build only what the plan consumes" survives statically
  twice over: only levels the plan probes get hash tables, and a relation
  that is only iterated at a single level skips the build entirely. The
  sort itself is the segmented radix kernel (kernels/radix_sort.py):
  level-by-level LSD passes inside the parent groups, with pass count set
  by each var's key width — jnp.lexsort remains only as the fallback for
  keys that may be negative (SPMD pad sentinels, weighted stage buffers).
  A StaticTrie is a registered pytree, so a prebuilt trie crosses the jit
  boundary as a plain *input* of device arrays.

* The PROBE program (make_executor / make_chain_executor) takes tries —
  prebuilt pytrees or raw column dicts, per alias — and runs the plan over
  a capacity-bounded frontier. A raw dict is built in-graph (the cold
  path, and the only path for weighted stage buffers, which exist only
  mid-chain); a prebuilt trie contributes zero build work to the call.
  Iteration is expand_counted (prefix-sum + binary-search addressing);
  probing is the hash_probe kernel; predicted-dead frontiers are compacted
  (kernels/compact.py). Bag semantics via a mult column; factorized
  counting decided statically from the plan.

* The cross-call TRIE CACHE (TrieCache / TRIE_CACHE) amortizes builds
  across calls, the COLT move that makes steady-state serving pay probe
  cost only. It is keyed by relation identity (weakref registry — entries
  die with their relations, see core/relcache.py) + level layout + budget,
  revalidated per column by host-array identity, and lazy per level: a
  schedule that probes a level the cached build skipped adds exactly that
  level's table; a level sequence prefix-compatible with a cached one
  reuses the cached sort order and pays no sorting pass for the shared
  prefix. Weighted (stage-output) tries are never cached: their rows are
  padded frontier lanes of one specific run, so reuse across runs would
  serve stale intermediates.

* Since PR 9 the cache has a DELTA path for relations mutated through
  core/relcache.py's append/delete API, replacing rebuild-on-any-change.
  A mutating relation's trie is padded to a power-of-two capacity bucket
  (_bucket), pad rows carrying PAD_KEY keys and multiplicity 0 so they
  sort to the tail and weigh nothing. An append sorts ONLY the delta
  (segmented radix kernel, the delta's own key width) and splices the
  sorted run into the cached level buffers with a rank-merge
  (_merge_append_jit): lex_searchsorted ranks each delta row against the
  old sorted order, position arithmetic scatters both runs into the new
  order, and the trie is rebuilt through the presorted constructor
  bypass — zero sort passes over old rows. The real row count crosses
  the jit boundary as a device scalar, so same-bucket appends reuse one
  compiled merge program. A delete tombstones rows in place
  (_retire_rows_jit zeroes their weights and refreshes group weights);
  when live/total drops below the state's compact_ratio, relcache
  compacts and the next access pays one honest rebuild. Counters
  (delta_merges, tombstone_refreshes) make the contract testable:
  appends move delta_merges while builds stands still.

Bushy plans run fully compiled (Sec 2.2): make_chain_executor strings every
stage's executor into ONE on-device program — a non-root stage runs with
agg=None, its output columns stay on device as a padded buffer (invalid
lanes stamped PAD_KEY with multiplicity 0), and the next stage builds a
*weighted* StaticTrie straight from that buffer, in-graph.

The shared-driver contract (one planning pass serves the local *and* the
distributed compiled paths — api.compiled_free_join and
distributed.spmd_count are both thin drivers over the same stack):

* The driver builds one optimizer.Stats cache and one StaticSchedule per
  stage and threads them through optimize -> capacity.plan_chain_capacities
  -> optimizer.estimate_prefixes -> make_executor. On a warm call the
  costly parts of that pass disappear: distinct counts come from the
  weakref registry (zero np.unique), AGM bounds from a memo, and the whole
  runner — capacity plan and compiled executors — from api._runner_cache.
  Plan *enumeration* (optimize's greedy search, pure host arithmetic over
  cached stats) still runs per call, because the runner key is derived
  from the chosen plan.
* make_executor builds the jit-able executor for one capacity vector.
  Buffer pressure is reported per node as *required totals*: agg="count"
  returns (count, need_expand, need_compact); agg=None returns (bound
  columns, valid mask, mult, need_expand, need_compact). Node i overflowed
  iff the need exceeds its capacity, and the need is the exact capacity the
  retry loop should jump to.
* AdaptiveExecutor drives the whole chain in an overflow-retry loop (grow
  exactly the offending node straight to its reported need; tighten=True
  also shrinks >2x-oversized buffers to measured needs once), caching one
  compiled executor per capacity-vector chain. run_relations is the warm
  serving surface: device uploads, built tries, and planning statistics all
  come from the registry, so a retry or tighten re-run recompiles the probe
  program but never rebuilds a trie.
* Zero-row relations are handled natively: an empty relation builds a
  StaticTrie whose every frontier expansion yields zero live lanes and
  whose probes match nothing, so drivers need no host-side empty gate.

make_count_fn/count_query keep the original count-only surface (manual
capacities, scalar overflow bit) for benchmarks and dry runs.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults, membudget, relcache
from repro.core.plan import FreeJoinPlan
from repro.kernels import ops

# Key stamped on the pad (invalid) lanes of a materialized stage buffer.
# Real join keys are dictionary-encoded int32 >= 0 and never reach int32
# max, so pad rows lose every probe immediately; correctness does not rest
# on that (their multiplicity is 0), it only keeps dead lanes short-lived.
PAD_KEY = np.int32(2**31 - 1)


@dataclass(frozen=True)
class _LevelOps:
    """Static decisions for one atom: which levels are probed/iterated."""

    levels: tuple[tuple[str, ...], ...]
    probed: tuple[bool, ...]  # per level: consumed by probe?


@dataclass(frozen=True)
class StaticSchedule:
    """One static walk of a plan, computed once per query and threaded
    through the whole driver stack (planner, estimator, executor builds).
    entries[i] = (node index, cover subatom, probe subatoms); level_ops maps
    alias -> per-level probe/iterate decisions."""

    entries: tuple
    level_ops: dict

    def __len__(self):
        return len(self.entries)

    def __iter__(self):
        return iter(self.entries)


def _static_schedule(plan: FreeJoinPlan) -> StaticSchedule:
    """Walk the plan once, statically: per node pick the cover (first listed
    — plans arrive factored), mark each atom level probe/iterate."""
    parts = plan.partitions()
    consumed: dict[str, int] = {a: 0 for a in parts}
    probed: dict[str, list[bool]] = {a: [False] * len(parts[a]) for a in parts}
    schedule = []
    for k, node in enumerate(plan.nodes):
        subs = [sa for sa in node if sa.vars]
        if not subs:
            continue
        covers = [sa for sa in plan.covers(k) if sa.vars and any(sa is s for s in subs)]
        cover = covers[0]
        probes = tuple(sa for sa in subs if sa is not cover)
        schedule.append((k, cover, probes))
        for sa in probes:
            probed[sa.alias][consumed[sa.alias]] = True
            consumed[sa.alias] += 1
        consumed[cover.alias] += 1
    level_ops = {a: _LevelOps(tuple(parts[a]), tuple(probed[a])) for a in parts}
    return StaticSchedule(entries=tuple(schedule), level_ops=level_ops)


class StaticTrie:
    """Sort-based trie with static shapes (see module docstring).

    Constructing one IS the build program; a built instance is a registered
    pytree of device arrays, so it can be returned from a jit'd build and
    fed to a jit'd probe program as an ordinary input. `key_bits` (one
    width per level var, in level order) routes the sort to the segmented
    radix kernel; None, an empty relation, or a weighted build fall back to
    jnp.lexsort (weighted/pad keys can be negative or PAD_KEY-wide).
    `init_order`/`presorted` seed the sort with a cached permutation
    already sorted by the first `presorted` level vars (TrieCache's
    prefix-compatible order sharing).

    `mult` (optional) marks a *weighted* trie built from another stage's
    padded output buffer: row i carries multiplicity mult[i] >= 0, and rows
    with mult 0 are padding (dead executor lanes) that must contribute
    nothing. Weighted tries keep two per-group aggregates — physical row
    counts (for last-level enumeration addressing) and mult sums (for
    factorized counting and bag multiplicity) — and the executor folds the
    per-row mult in (and kills mult-0 lanes) whenever it enumerates physical
    rows."""

    def __init__(
        self,
        cols: dict[str, jnp.ndarray],
        lops: _LevelOps,
        impl: str,
        budget: int = 32,
        mult: jnp.ndarray | None = None,
        key_bits: tuple[int, ...] | None = None,
        init_order: jnp.ndarray | None = None,
        presorted: int = 0,
    ):
        self.impl = impl
        self.budget = budget
        self.lops = lops
        self.L = len(lops.levels)
        self.levels = lops.levels
        some = next(iter(cols.values()))
        self.empty = some.shape[0] == 0
        if self.empty:
            # zero-row relation: keep one sentinel row so every downstream
            # gather has a real operand; iter_counts/rows_under/probe below
            # force zero live lanes, so the sentinel is never observable
            cols = {k: jnp.full(1, -1, jnp.int32) for k in cols}
            some = next(iter(cols.values()))
            mult = None
        n = some.shape[0]
        self.n = n
        self.cols = {k: v.astype(jnp.int32) for k, v in cols.items()}
        self.mult_col = None if mult is None else mult.astype(jnp.int32)
        self.total_mult = None if mult is None else jnp.sum(self.mult_col)
        self.trivial = self.L == 1 and not lops.probed[0]
        self.order = None
        self.sorted_cols = None
        self.g = self.kpos = None
        self.child_base = self.child_counts = self.row_count = None
        self.row_weight = self.tables = None
        if self.trivial:  # pure cover: iterate the base table, zero build
            return
        all_vars = [v for lv in lops.levels for v in lv]
        if init_order is not None and presorted >= len(all_vars) and not self.empty:
            # delta-merge build (TrieCache._merge_append): the caller already
            # holds the full lexicographic permutation — spliced from a cached
            # sorted run and a sorted delta — so the build pays zero sorting
            # passes, only the group-structure scans below
            order = init_order
        elif key_bits is not None and not self.empty and mult is None:
            order = ops.segmented_sort(
                [self.cols[v] for v in all_vars],
                tuple(key_bits),
                impl=impl,
                init_order=init_order,
                presorted=presorted,
            )
        else:
            order = jnp.lexsort(tuple(self.cols[v] for v in reversed(all_vars)))
        self.order = order.astype(jnp.int32)
        sc = {v: self.cols[v][order] for v in all_vars}
        self.sorted_cols = sc
        sm = None if self.mult_col is None else self.mult_col[order]
        idx = jnp.arange(n, dtype=jnp.int32)
        # depth-d group ids for d = 0..L, flags for d = 1..L
        self.g = [jnp.zeros(n, jnp.int32)]  # g[0] = root
        self.kpos = [jnp.zeros(1, jnp.int32)]  # first position of each group
        flag = jnp.zeros(n, dtype=bool)
        self.child_base, self.child_counts, self.row_count, self.tables = [], [], [], []
        self.row_weight = []
        for d, lv in enumerate(lops.levels):
            diff = jnp.zeros(n, dtype=bool).at[0].set(True)
            for v in lv:
                diff = diff.at[1:].set(diff[1:] | (sc[v][1:] != sc[v][:-1]))
            flag = flag | diff
            flag = flag.at[0].set(True)
            gd1 = (jnp.cumsum(flag.astype(jnp.int32)) - 1).astype(jnp.int32)  # g[d+1]
            # children of each depth-d group (counts over depth-(d+1) firsts)
            ccnt = jax.ops.segment_sum(flag.astype(jnp.int32), self.g[d], num_segments=n)
            cbase = jnp.cumsum(ccnt) - ccnt
            kp = jnp.zeros(n + 1, jnp.int32).at[jnp.where(flag, gd1, n)].set(idx, mode="drop")
            rcnt = jax.ops.segment_sum(jnp.ones(n, jnp.int32), gd1, num_segments=n)
            self.g.append(gd1)
            self.kpos.append(kp[:n])
            self.child_base.append(cbase.astype(jnp.int32))
            self.child_counts.append(ccnt.astype(jnp.int32))
            self.row_count.append(rcnt)
            if sm is not None:
                self.row_weight.append(jax.ops.segment_sum(sm, gd1, num_segments=n))
            # probed levels get their hash table; one shared construction
            # with the lazy path (build_level_table), so eagerly- and
            # lazily-built tables can never drift
            self.tables.append(self.build_level_table(d, budget) if lops.probed[d] else None)

    # -- pytree protocol: a built trie crosses jit boundaries as an input --

    def tree_flatten(self):
        children = (
            self.cols,
            self.mult_col,
            self.total_mult,
            self.order,
            self.sorted_cols,
            self.g,
            self.kpos,
            self.child_base,
            self.child_counts,
            self.row_count,
            self.row_weight,
            self.tables,
        )
        aux = (self.lops, self.impl, self.budget, self.n, self.empty)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        t = object.__new__(cls)
        (
            t.cols,
            t.mult_col,
            t.total_mult,
            t.order,
            t.sorted_cols,
            t.g,
            t.kpos,
            t.child_base,
            t.child_counts,
            t.row_count,
            t.row_weight,
            t.tables,
        ) = children
        t.lops, t.impl, t.budget, t.n, t.empty = aux
        t.levels = t.lops.levels
        t.L = len(t.levels)
        t.trivial = t.L == 1 and not t.lops.probed[0]
        return t

    def build_level_table(self, d: int, budget: int | None = None):
        """Build the depth-d probe table on an already-sorted trie — the
        lazy-COLT path for a schedule that probes a level the cached build
        skipped. Device work is exactly one table build; the sort and the
        group structure are reused."""
        assert not self.trivial and self.g is not None
        lv = self.levels[d]
        n = self.n
        idx = jnp.arange(n, dtype=jnp.int32)
        gd1 = self.g[d + 1]
        flag = jnp.zeros(n, dtype=bool).at[0].set(True)
        flag = flag.at[1:].set(gd1[1:] != gd1[:-1])
        parent = jnp.where(flag, self.g[d], -idx - 2)
        key_rows = jnp.stack(
            [parent] + [jnp.where(flag, self.sorted_cols[v], 0) for v in lv], axis=1
        )
        return ops.build_table(key_rows, budget=budget or self.budget)

    def table_view(self, probed: tuple[bool, ...]) -> "StaticTrie":
        """A shallow view sharing every array, exposing tables only where
        `probed` asks — so the executor's input pytree structure depends
        only on the schedule, not on how many tables the cached build has
        accumulated."""
        if self.trivial:
            return self
        children, aux = self.tree_flatten()
        lops, impl, budget, n, empty = aux
        aux = (replace(lops, probed=tuple(probed)), impl, budget, n, empty)
        view = self.tree_unflatten(aux, children)
        view.tables = [t if p else None for t, p in zip(self.tables, probed)]
        return view

    # depth-d group sizes (weighted by mult for stage tries): drives
    # factorized count and last-level probe multiplicity
    def rows_under(self, d: int, gids: jnp.ndarray) -> jnp.ndarray:
        if self.empty:
            return jnp.zeros(gids.shape, jnp.int32)
        if self.trivial or d == 0:
            if self.total_mult is not None:
                return jnp.broadcast_to(self.total_mult, gids.shape)
            return jnp.full(gids.shape, self.n, jnp.int32)
        if self.mult_col is not None:
            return self.row_weight[d - 1][gids]
        return self.row_count[d - 1][gids]

    # physical depth-d group sizes: addressing for last-level enumeration
    def _phys_rows(self, d: int, gids: jnp.ndarray) -> jnp.ndarray:
        if self.trivial or d == 0:
            return jnp.full(gids.shape, self.n, jnp.int32)
        return self.row_count[d - 1][gids]

    def probe(self, d: int, gids, key_cols):
        if self.empty:  # nothing to match: kill every probing lane
            return jnp.full(gids.shape, -1, jnp.int32)
        q = jnp.stack([gids.astype(jnp.int32)] + [c.astype(jnp.int32) for c in key_cols], axis=1)
        p = ops.probe(self.tables[d], q, impl=self.impl)
        child = self.g[d + 1][jnp.clip(p, 0, self.n - 1)]
        return jnp.where(p >= 0, child, -1)

    def iter_counts(self, d: int, gids, last: bool):
        """(base, counts) for expand_counted at level d from groups `gids`.
        last=True enumerates rows; otherwise enumerates child groups."""
        z = jnp.zeros(gids.shape, jnp.int32)
        if self.empty:  # every expansion yields zero live lanes
            return z, z
        if self.trivial:
            return z, jnp.full(gids.shape, self.n, jnp.int32)
        if last:
            base = (
                self.kpos[d][jnp.clip(gids, 0, self.n - 1)]
                if d > 0
                else jnp.zeros(gids.shape, jnp.int32)
            )
            counts = self._phys_rows(d, gids)
            return base, counts
        return self.child_base[d][gids], self.child_counts[d][gids]

    def bind_iter(self, d: int, members, last: bool):
        """Column values bound by iterating; members from expand_counted.
        Returns (cols list in level-var order, new_gids or None)."""
        lv = self.levels[d]
        if self.trivial:
            return [self.cols[v][members] for v in lv], None
        if last:
            rows = self.order[members]
            return [self.cols[v][rows] for v in lv], self.g[d + 1][members]
        kp = self.kpos[d + 1][members]
        return [self.sorted_cols[v][kp] for v in lv], members

    def iter_mult(self, members) -> jnp.ndarray | None:
        """Per-row multiplicity of the physical rows enumerated by a
        last-level bind_iter (None for unweighted tries: each row counts 1).
        A zero marks a pad row — the executor kills that lane."""
        if self.mult_col is None:
            return None
        rows = members if self.trivial else self.order[members]
        return self.mult_col[rows]


jax.tree_util.register_pytree_node(
    StaticTrie, StaticTrie.tree_flatten, StaticTrie.tree_unflatten
)


def build_trie(
    cols: dict[str, jnp.ndarray],
    lops: _LevelOps,
    *,
    impl: str = "jnp",
    budget: int = 32,
    mult: jnp.ndarray | None = None,
    key_bits: tuple[int, ...] | None = None,
    init_order: jnp.ndarray | None = None,
    presorted: int = 0,
) -> StaticTrie:
    """The explicit build step: columns in, a StaticTrie pytree of device
    arrays out. Traceable — called inside the probe program for raw column
    dicts and weighted stage buffers, or under its own jit (see
    _build_trie_jit) by the cross-call cache."""
    return StaticTrie(
        cols,
        lops,
        impl,
        budget,
        mult=mult,
        key_bits=key_bits,
        init_order=init_order,
        presorted=presorted,
    )


@functools.partial(
    jax.jit, static_argnames=("lops", "impl", "budget", "key_bits", "presorted")
)
def _build_trie_jit(cols, lops, impl, budget, key_bits, init_order, presorted):
    return build_trie(
        cols,
        lops,
        impl=impl,
        budget=budget,
        key_bits=key_bits,
        init_order=init_order,
        presorted=presorted,
    )


def _bucket(n: int, block: int = 1024) -> int:
    """Physical capacity for a mutating relation's padded trie: the next
    power of two >= n (min `block`). Appends within a bucket keep every
    array shape fixed — the merge program retraces only at bucket growth."""
    return max(block, 1 << max(0, n - 1).bit_length())


@functools.partial(jax.jit, static_argnames=("lops", "impl", "budget"))
def _build_weighted_jit(cols, mult, lops, impl, budget):
    """Full rebuild of a mutating relation's padded+weighted trie (cold
    build, post-compaction, or a pruned delta log). Pads carry PAD_KEY keys
    and mult 0; the lexsort routes them to the tail, where every later
    merge expects them."""
    return build_trie(cols, lops, impl=impl, budget=budget, mult=mult)


@functools.partial(
    jax.jit,
    static_argnames=("lops", "impl", "budget", "cap", "delta_bits", "has_mult"),
)
def _merge_append_jit(
    old_cols,
    old_mult,
    old_sorted,
    old_order,
    n_real,
    delta_cols,
    *,
    lops,
    impl,
    budget,
    cap,
    delta_bits,
    has_mult,
):
    """Splice a sorted delta run into a cached padded trie — the delta
    build program. Sorts ONLY the delta (segmented radix kernel over the
    delta's own key widths), binary-searches each delta tuple's slot in the
    cached sorted run (ops.lex_searchsorted), and derives the merged
    permutation arithmetically; the constructor's presorted bypass then
    rebuilds the group structure with zero sorting passes.

    Shape discipline: every input keeps its bucket capacity and `n_real`
    (the live+tombstone prefix length) is a DEVICE scalar, so a stream of
    same-size appends within one bucket re-enters one compiled program —
    no retrace per append. Pad rows (keys PAD_KEY, mult 0) sort after all
    real rows, so they stay a contiguous tail that the merge shifts and
    renormalizes with pure elementwise ops; scatters use mode="drop" for
    the pads pushed past the (possibly grown) capacity `cap`."""
    flat = [v for lv in lops.levels for v in lv]
    some = next(iter(delta_cols.values()))
    m = some.shape[0]
    c_old = next(iter(old_cols.values())).shape[0]
    n_new = n_real + m  # dynamic value, static bound cap >= host n_real + m
    idx = jnp.arange(cap, dtype=jnp.int32)

    def extend(a, fill):
        if cap > c_old:
            a = jnp.concatenate([a, jnp.full(cap - c_old, fill, jnp.int32)])
        return a

    new_cols = {}
    for v in old_cols:
        delta = delta_cols[v].astype(jnp.int32)
        new_cols[v] = jax.lax.dynamic_update_slice(
            extend(old_cols[v], PAD_KEY), delta, (n_real,)
        )
    om = old_mult if has_mult else jnp.ones(c_old, jnp.int32)
    om = jnp.where(jnp.arange(c_old, dtype=jnp.int32) < n_real, om, 0)
    new_mult = jax.lax.dynamic_update_slice(
        extend(om, 0), jnp.ones(m, jnp.int32), (n_real,)
    )
    new_mult = jnp.where(idx < n_new, new_mult, 0)
    if len(lops.levels) == 1 and not lops.probed[0]:
        # trivial (cover-only) trie: no order to maintain, just new columns
        return build_trie(new_cols, lops, impl=impl, budget=budget, mult=new_mult)
    # sort the delta among itself, then locate each tuple's splice slot
    delta_order = ops.segmented_sort(
        [delta_cols[v].astype(jnp.int32) for v in flat], tuple(delta_bits), impl=impl
    ).astype(jnp.int32)
    ds = {v: delta_cols[v].astype(jnp.int32)[delta_order] for v in flat}
    # rank in the cached sorted run; real keys < PAD_KEY, so ranks never
    # land inside the pad tail and the merged real prefix is exactly n_new
    rank = ops.lex_searchsorted([old_sorted[v] for v in flat], [ds[v] for v in flat])
    pos_delta = rank + jnp.arange(m, dtype=jnp.int32)
    k = jnp.arange(c_old, dtype=jnp.int32)
    pos_old = k + jnp.searchsorted(rank, k, side="right").astype(jnp.int32)
    # delta rows take indices [n_real, n_new); old pads shift up by m
    adj = old_order + jnp.where(old_order >= n_real, m, 0).astype(jnp.int32)
    new_order = jnp.zeros(cap, jnp.int32)
    new_order = new_order.at[pos_old].set(adj, mode="drop")
    new_order = new_order.at[pos_delta].set(n_real + delta_order, mode="drop")
    # pads are interchangeable: identity-map the tail so `new_order` stays a
    # permutation regardless of how many pads the scatters dropped
    new_order = jnp.where(idx >= n_new, idx, new_order)
    return build_trie(
        new_cols,
        lops,
        impl=impl,
        budget=budget,
        mult=new_mult,
        init_order=new_order,
        presorted=len(flat),
    )


@jax.jit
def _retire_rows_jit(mult, order, groups, rows):
    """Tombstone catch-up on a cached trie: zero the rows' multiplicity and
    refresh the per-level weight aggregates. The sort order, group
    structure, and hash tables are untouched — dead rows keep their slots
    and simply weigh nothing."""
    mult = mult.at[rows].set(0)
    total = jnp.sum(mult)
    sm = mult[order] if order is not None else mult
    weights = [
        jax.ops.segment_sum(sm, gd1, num_segments=mult.shape[0]) for gd1 in groups
    ]
    return mult, total, weights


def device_columns(rel) -> dict[str, jnp.ndarray]:
    """Registry-cached int32 device upload of a relation's columns: each
    host column is transferred once per (relation object, column object)
    and the upload dies with the relation. Replacing a column in
    rel.columns re-uploads exactly that column (identity check); mutating a
    numpy array in place is not detectable and not supported — replace the
    array."""
    return {
        v: relcache.memo(
            relcache.REGISTRY,
            rel,
            "dev_cols",
            v,
            rel.columns[v],
            lambda v=v: jnp.asarray(rel.columns[v], jnp.int32),
        )
        for v in rel.schema
    }


class TrieCache:
    """Cross-call StaticTrie cache (see module docstring).

    One entry per (relation object, level layout, impl, budget), held in
    the weakref registry so it dies with the relation; revalidated per
    column by host-array identity, so a replaced column rebuilds. Lazy per
    level: a request probing a level the cached build skipped adds only
    that level's table (build_level_table); a level-var sequence sharing a
    prefix with a cached one seeds the sort with the cached order and skips
    the shared passes. Weighted builds are refused — stage-output tries are
    one run's padded lanes and must never be served across runs.

    MUTATING relations (those with a relcache.MutationState, i.e. touched
    by relcache.append/delete) take the versioned DELTA path instead of
    identity revalidation. Their entries carry the mutation version they
    materialized at plus `n_real` (live+tombstone row prefix), and the trie
    itself is padded to a power-of-two bucket — pad rows carry PAD_KEY keys
    and multiplicity 0, sorted to a contiguous tail. Serving one then means:

    * version match — pure cache hit, zero device work;
    * version behind — replay `deltas_since`: an append sorts ONLY the
      delta and splices it into the cached sorted run (_merge_append_jit,
      zero full re-sorts; `delta_merges` counts these), a delete refreshes
      the weight aggregates in place (`tombstone_refreshes`);
    * log pruned / compaction crossed / negative delta keys — full padded
      weighted rebuild (counted in `builds`, like any cold build).

    A trie built BEFORE the relation's first mutation is adopted as the
    version-0 merge base when it matches the state's version-0 device
    columns, so warm-then-stream never pays a rebuild.

    Counters (builds/table_builds/hits/order_shares/delta_merges/
    tombstone_refreshes) are the observable contract the tests lock: a
    repeated identical call must be all hits, and an append followed by a
    query must bump delta_merges — never builds.
    """

    def __init__(self, registry: relcache.RelationRegistry | None = None):
        self._reg = registry or relcache.REGISTRY
        self.builds = 0  # full trie builds (sort + structure + tables)
        self.table_builds = 0  # lazy per-level table additions
        self.hits = 0  # fully served from cache: zero device work
        self.order_shares = 0  # builds that reused a cached sort order
        self.delta_merges = 0  # appends absorbed by sorted-run splicing
        self.tombstone_refreshes = 0  # deletes absorbed by weight refresh

    def _key_bits(self, rel, flat_vars) -> tuple[int, ...] | None:
        """Static per-var key widths for the radix sort, from the host
        columns (cached per column object). None when any key may be
        negative — those builds stay on lexsort."""
        def width_of(host):
            def compute():
                if len(host) == 0:
                    return 1
                if int(host.min()) < 0:
                    return None
                return max(1, int(host.max()).bit_length())

            return compute

        bits = []
        for v in flat_vars:
            host = rel.columns[v]
            w = relcache.memo(self._reg, rel, "key_bits", v, host, width_of(host))
            if w is None:
                return None
            bits.append(w)
        return tuple(bits)

    def get(
        self,
        rel,
        dev_cols: dict[str, jnp.ndarray],
        lops: _LevelOps,
        *,
        impl: str = "jnp",
        budget: int = 32,
        mult=None,
    ) -> StaticTrie:
        assert mult is None, "weighted (stage-output) tries are never cached"
        ns = self._reg.namespace(rel, "tries")
        flat = tuple(v for lv in lops.levels for v in lv)
        used = {v: dev_cols[v] for v in flat}
        trivial = len(lops.levels) == 1 and not lops.probed[0]
        # trivial-ness is part of the identity: a cover-only (table-less,
        # order-less) trie must never be served to a schedule that probes
        key = (lops.levels, impl, budget, trivial)
        st = relcache.mutation_state(rel)
        if st is not None:
            return self._get_mutating(rel, st, dev_cols, lops, flat, key, impl, budget)
        entry = ns.get(key)
        if (
            entry is not None
            and entry.get("version") is None
            and all(entry["cols"][v] is used[v] for v in flat)
        ):
            view = self._serve(entry["trie"], lops, budget, count_hit=True)
            self._govern(rel, ns, key)
            return view
        # miss: build, seeding the sort with any prefix-compatible cached
        # order over the same (identical) columns
        key_bits = self._key_bits(rel, flat)
        init_order, presorted = None, 0
        if key_bits is not None and not trivial:
            for (levels2, _i2, _b2, _t2), e2 in ns.items():
                donor = e2["trie"]
                if donor.order is None or e2.get("version") is not None:
                    continue  # padded mutating orders never seed plain builds
                flat2 = tuple(v for lv in levels2 for v in lv)
                share = 0
                while (
                    share < min(len(flat), len(flat2))
                    and flat[share] == flat2[share]
                    and e2["cols"][flat2[share]] is used[flat[share]]
                ):
                    share += 1
                if share > presorted:
                    init_order, presorted = donor.order, share
        trie = _build_trie_jit(used, lops, impl, budget, key_bits, init_order, presorted)
        ns[key] = {"trie": trie, "cols": used}
        self.builds += 1
        if presorted:
            self.order_shares += 1
        self._govern(rel, ns, key)
        return trie.table_view(lops.probed)

    def _govern(self, rel, ns, key) -> None:
        """Account the cached entry's device bytes with the memory
        governor (an LRU touch on every serve, a resize when lazy tables
        or delta merges changed the footprint). If the governor sheds —
        this trie alone cannot fit the budget even after evicting every
        cold entry — the entry is dropped and the trie serves this one
        call uncached, keeping the governed-bytes invariant intact."""
        entry = ns.get(key)
        if entry is None:
            return
        token = ("trie", id(rel), key)
        try:
            membudget.GOVERNOR.account(
                token,
                membudget.trie_nbytes(entry["trie"]),
                evict=lambda _ns=ns, _k=key: _ns.pop(_k, None),
                owner=rel,
            )
        except membudget.MemoryBudgetError:
            ns.pop(key, None)
            membudget.GOVERNOR.release(token)

    def _serve(self, trie: StaticTrie, lops, budget, *, count_hit: bool):
        """Fill any probe tables the request needs that the cached build
        skipped (the lazy-COLT path), then hand out a probed view."""
        missing = [
            d
            for d, p in enumerate(lops.probed)
            if p and not trie.trivial and trie.tables[d] is None
        ]
        for d in missing:
            trie.tables[d] = trie.build_level_table(d, budget)
            self.table_builds += 1
        if count_hit and not missing:
            self.hits += 1
        return trie.table_view(lops.probed)

    def _get_mutating(self, rel, st, dev_cols, lops, flat, key, impl, budget):
        """Serve a mutating relation: version-matched hit, delta catch-up
        (merge appends, retire deletes), or full padded rebuild."""
        ns = self._reg.namespace(rel, "tries")
        entry = ns.get(key)
        if entry is not None and entry.get("version") is None:
            # built before the first mutation: adopt as the version-0 merge
            # base iff it is over the state's version-0 device columns (and
            # no compaction/pruning has moved the base past version 0)
            trie = entry["trie"]
            if (
                st.base_version == 0
                and not trie.empty
                and all(entry["cols"].get(v) is st.dev0.get(v) for v in flat)
            ):
                entry["version"] = 0
                entry["n_real"] = trie.n
            else:
                entry = None
        deltas = None
        if entry is not None:
            deltas = st.deltas_since(entry["version"])
            if deltas is None or entry["trie"].empty:
                entry = None  # pruned log or sentinel empty trie: rebuild
        if entry is not None:
            trie = entry["trie"]
            if not deltas:
                view = self._serve(trie, lops, budget, count_hit=True)
                self._govern(rel, ns, key)
                return view
            for _ver, kind, payload in deltas:
                if kind == "append":
                    merged = self._merge_append(
                        trie, entry["n_real"], payload, lops, impl, budget
                    )
                    if merged is None:  # negative delta keys: lexsort only
                        entry = None
                        break
                    trie = merged
                    entry["n_real"] += len(next(iter(payload.values())))
                    self.delta_merges += 1
                else:
                    self._retire(trie, payload)
                    self.tombstone_refreshes += 1
            if entry is not None:
                entry["trie"] = trie
                entry["cols"] = dict(trie.cols)
                entry["version"] = st.version
                view = self._serve(trie, lops, budget, count_hit=False)
                self._govern(rel, ns, key)
                return view
        # full rebuild, padded to the bucket and weighted by the liveness
        # mask, so later appends merge and later deletes retire in place
        cap = _bucket(st.total)
        pad = cap - st.total
        used = {}
        for v in flat:
            dc = dev_cols[v]
            used[v] = (
                jnp.concatenate([dc, jnp.full(pad, PAD_KEY, jnp.int32)]) if pad else dc
            )
        if st.mult is not None:
            hm = st.mult if pad == 0 else np.concatenate([st.mult, np.zeros(pad, np.int32)])
            mult = jax.device_put(np.ascontiguousarray(hm))
        else:
            mult = (jnp.arange(cap, dtype=jnp.int32) < st.total).astype(jnp.int32)
        trie = _build_weighted_jit(used, mult, lops, impl, budget)
        ns[key] = {
            "trie": trie,
            "cols": dict(trie.cols),
            "version": st.version,
            "n_real": st.total,
        }
        self.builds += 1
        view = self._serve(trie, lops, budget, count_hit=False)
        self._govern(rel, ns, key)
        return view

    def _merge_append(self, trie, n_real, payload, lops, impl, budget):
        """Host wrapper for one append log entry: delta key widths, bucket
        growth, explicit device_put of the delta, and the probed-union lops
        (a merge rebuilds every table the cached trie had accumulated, so
        other schedules stay warm). Returns None when the delta has
        negative keys — the radix delta sort cannot order those."""
        flat = tuple(v for lv in lops.levels for v in lv)
        m = len(next(iter(payload.values())))
        bits = []
        for v in flat:
            col = payload[v]
            if int(col.min()) < 0:
                return None
            bits.append(max(1, int(col.max()).bit_length()))
        cap = _bucket(n_real + m)
        delta_dev = {
            v: jax.device_put(np.ascontiguousarray(payload[v].astype(np.int32)))
            for v in flat
        }
        if trie.trivial:
            mlops = lops
        else:
            mlops = replace(
                lops,
                probed=tuple(
                    (t is not None) or p for t, p in zip(trie.tables, lops.probed)
                ),
            )
        return _merge_append_jit(
            {v: trie.cols[v] for v in flat},
            trie.mult_col,
            trie.sorted_cols,
            trie.order,
            jax.device_put(np.int32(n_real)),
            delta_dev,
            lops=mlops,
            impl=impl,
            budget=budget,
            cap=cap,
            delta_bits=tuple(bits),
            has_mult=trie.mult_col is not None,
        )

    def _retire(self, trie, rows):
        """Apply one delete log entry to the cached trie in place: rows are
        host positions, which by the padding invariant are trie row indices
        verbatim. Order, groups, and tables are untouched."""
        mult = trie.mult_col
        if mult is None:
            mult = jnp.ones(trie.n, jnp.int32)
        groups = [] if trie.trivial else trie.g[1:]
        mult, total, weights = _retire_rows_jit(
            mult, trie.order, groups, jax.device_put(rows)
        )
        trie.mult_col = mult
        trie.total_mult = total
        if not trie.trivial:
            trie.row_weight = weights


TRIE_CACHE = TrieCache()


def make_executor(
    plan: FreeJoinPlan,
    capacities,
    *,
    compact_to=None,
    compact_probe=None,
    impl: str = "jnp",
    budget: int = 32,
    agg: str | None = "count",
    schedule: StaticSchedule | None = None,
    filters: tuple = (),
    filter_kill: bool = True,
):
    """Build a jit-able probe program for `plan` (see module docstring).

    capacities: one static expansion capacity per executed node; compact_to:
    optional per-node compaction target (None = keep the buffer);
    compact_probe: per node, how many probes run before compacting (default
    all — compact after the node; smaller values compact mid-node so the
    remaining probes run at the squeezed width); schedule: the query's
    StaticSchedule if the driver already computed it (None = walk the plan
    here). Returns fn(rel_data, rel_mults) ->
      agg="count":  (count, need_expand, need_compact)
      agg=None:     (bound, valid, mult, need_expand, need_compact)
    rel_data maps alias -> either a prebuilt StaticTrie (the warm path:
    zero build work in this call) or {var: (N,) int32} raw columns (built
    in-graph — the cold path, and the only path for weighted stage
    buffers). rel_mults (optional) maps an alias to a per-row multiplicity
    vector; such a relation is a *weighted* (stage-output) buffer whose
    mult-0 rows are padding — see StaticTrie. rel_data may contain extra
    aliases (the chain driver passes one growing dict); only the plan's are
    read. need_expand/need_compact are (num_executed_nodes,) int32 vectors
    of required totals: need_expand[i] is the lane count node i's expansion
    produced, need_compact[i] the live count at its compact point (0 when
    the node doesn't expand/compact). Node i overflowed iff
    need_expand[i] > capacities[i] (resp. need_compact[i] > compact_to[i]);
    the need is the exact capacity the adaptive runner should jump to.

    filters: ((var, const_index), ...) — equality selections whose
    *constants live outside the compiled program*: the run fn gains a
    third argument `filter_consts`, a traced int32 vector, compared
    against `bound[var]` the moment `var` is bound. Because the constant
    is a runtime value, every query of a plan template (same structure,
    different constants) shares ONE compiled executor. Two dispositions
    for the comparison's outcome:

    * filter_kill=True (single-query serving): the comparison ANDs into
      `valid` — filter-dead lanes stop probing immediately and compaction
      squeezes them out, so a selective constant makes the whole run
      cheaper.
    * filter_kill=False (batched serving): the comparison ANDs into a
      SEPARATE per-lane mask (`fvalid`) that rides along the frontier and
      folds in only at the terminal count/output. `valid`, every
      expansion count, every compaction, every probe — the entire
      frontier *layout* — stays constant-independent, so under jax.vmap
      over a (B, F) constants matrix the whole probe pipeline is computed
      ONCE and shared across lanes; only the mask ops and the final
      reduction batch. This is what makes one batched dispatch of B
      queries cost ~one unfiltered query instead of B filtered ones.
    """
    plan.validate()
    filters = tuple(filters)
    filter_idx = {v: int(i) for v, i in filters}
    unknown = set(filter_idx) - set(plan.query.variables)
    assert not unknown, f"filter vars not bound by this plan: {sorted(unknown)}"
    if schedule is None:
        schedule = _static_schedule(plan)
    level_ops = schedule.level_ops
    schedule = schedule.entries
    nsched = len(schedule)
    capacities = tuple(int(c) for c in capacities[:nsched])
    assert len(capacities) == nsched, "one capacity per executed node"
    compact_to = tuple(compact_to[:nsched]) if compact_to is not None else (None,) * nsched
    assert len(compact_to) == nsched, "one compaction target per executed node"
    compact_probe = (
        tuple(compact_probe[:nsched])
        if compact_probe
        else tuple(len(probes) for _, _, probes in schedule)
    )
    assert len(compact_probe) == nsched, "one compact point per executed node"

    def as_trie(src, lops: _LevelOps, mult):
        if isinstance(src, StaticTrie):
            assert src.levels == lops.levels, "prebuilt trie level mismatch"
            for d, p in enumerate(lops.probed):
                assert not p or src.trivial or src.tables[d] is not None, (
                    f"prebuilt trie missing probed level-{d} table"
                )
            return src
        return build_trie(src, lops, impl=impl, budget=budget, mult=mult)

    def run(
        rel_data: dict[str, object],
        rel_mults: dict[str, jnp.ndarray] | None = None,
        filter_consts: jnp.ndarray | None = None,
    ):
        assert not filter_idx or filter_consts is not None, (
            "this executor was built with filters; pass filter_consts"
        )
        mults = rel_mults or {}
        tries = {
            a: as_trie(rel_data[a], level_ops[a], mults.get(a)) for a in level_ops
        }
        depth = {a: 0 for a in level_ops}
        # frontier
        cap = 1
        valid = jnp.ones(1, dtype=bool)
        mult = jnp.ones(1, jnp.int32)  # int64 needs x64; counts < 2^31 here
        bound: dict[str, jnp.ndarray] = {}
        gid: dict[str, jnp.ndarray] = {}
        # mask-mode filter state (filter_kill=False): per-lane liveness that
        # never feeds the frontier layout — created at the first filter
        # comparison, gathered alongside the frontier, folded in at the end
        fvalid: list = [None]
        need_expand = [jnp.zeros((), jnp.int32) for _ in range(nsched)]
        need_compact = [jnp.zeros((), jnp.int32) for _ in range(nsched)]

        def squeeze(bound, gid, mult, valid, cap, c_compact, i):
            """Pack the valid lanes into a fresh c_compact-wide frontier."""
            src, live = ops.compact_indices(valid, c_compact, impl=impl)
            need_compact[i] = live
            srcc = jnp.clip(src, 0, cap - 1)
            bound = {v: a[srcc] for v, a in bound.items()}
            gid = {a: arr[srcc] for a, arr in gid.items()}
            mult = mult[srcc]
            if fvalid[0] is not None:
                fvalid[0] = fvalid[0][srcc]
            valid = jnp.arange(c_compact, dtype=jnp.int32) < live
            return bound, gid, mult, valid, c_compact

        for i, ((k, cover, probes), c_next, c_compact, cp_idx) in enumerate(
            zip(schedule, capacities, compact_to, compact_probe)
        ):
            t = tries[cover.alias]
            d = depth[cover.alias]
            g = gid.get(cover.alias, jnp.zeros(cap, jnp.int32))
            last = d == t.L - 1
            # a filtered var can never take the factorized-count shortcut:
            # its comparison against the constant needs the bound values
            needed = _needed_later_static(plan, k, probes, agg) | set(filter_idx)
            if agg == "count" and not (set(cover.vars) & needed) and last and not (
                set(cover.vars) & set(bound)
            ):
                # factorized count (static decision)
                mult = mult * jnp.where(valid, t.rows_under(d, g), 1).astype(jnp.int32)
                gid.pop(cover.alias, None)
                depth[cover.alias] = t.L
            else:
                base, counts = t.iter_counts(d, g, last)
                counts = jnp.where(valid, counts, 0)
                fr, member, vnew, total = ops.expand_counted(base, counts, c_next, impl=impl)
                need_expand[i] = total
                frc = jnp.clip(fr, 0, cap - 1)
                memc = jnp.clip(member, 0, max(t.n - 1, 0))
                bound = {v: a[frc] for v, a in bound.items()}
                gid = {a: arr[frc] for a, arr in gid.items()}
                mult = mult[frc]
                if fvalid[0] is not None:
                    fvalid[0] = fvalid[0][frc]
                valid = vnew
                cap = c_next
                cols, new_g = t.bind_iter(d, memc, last)
                for v, cvals in zip(cover.vars, cols):
                    if v in bound:  # semijoin on re-bound vars
                        valid = valid & (bound[v] == cvals)
                    else:
                        bound[v] = cvals
                        if v in filter_idx:  # constant selection, applied
                            # the moment the var is bound
                            hit = cvals == filter_consts[filter_idx[v]]
                            if filter_kill:  # dead lanes never reach a probe
                                valid = valid & hit
                            elif fvalid[0] is None:  # layout-neutral mask
                                fvalid[0] = hit
                            else:
                                fvalid[0] = fvalid[0] & hit
                depth[cover.alias] = d + 1
                if new_g is None or depth[cover.alias] == t.L:
                    # last-level iteration enumerates physical rows, so bag
                    # multiplicity is already accounted for — except on a
                    # weighted (stage-output) trie, whose per-row mult folds
                    # in here and whose mult-0 pad rows die on the spot.
                    rm = t.iter_mult(memc)
                    if rm is not None:
                        mult = mult * jnp.where(valid, rm, 1)
                        valid = valid & (rm > 0)
                    gid.pop(cover.alias, None)
                else:
                    gid[cover.alias] = new_g
            compacted = False
            for j, sa in enumerate(probes):
                tp = tries[sa.alias]
                dp = depth[sa.alias]
                gp = gid.get(sa.alias, jnp.zeros(cap, jnp.int32))
                keys = [bound[v] for v in sa.vars]
                child = tp.probe(dp, jnp.where(valid, gp, -1), keys)
                valid = valid & (child >= 0)
                childc = jnp.clip(child, 0, max(tp.n - 1, 0))
                depth[sa.alias] = dp + 1
                if depth[sa.alias] == tp.L:
                    mult = mult * jnp.where(valid, tp.rows_under(tp.L, childc), 1).astype(jnp.int32)
                    gid.pop(sa.alias, None)
                else:
                    gid[sa.alias] = childc
                if c_compact is not None and not compacted and j + 1 >= cp_idx and c_compact < cap:
                    # squeeze dead lanes out mid-node: the remaining probes
                    # (and all later nodes) run at c_compact
                    bound, gid, mult, valid, cap = squeeze(
                        bound, gid, mult, valid, cap, c_compact, i
                    )
                    compacted = True
            if c_compact is not None and not compacted and c_compact < cap:
                # probe-less node (or unreached compact point): after-node
                bound, gid, mult, valid, cap = squeeze(bound, gid, mult, valid, cap, c_compact, i)
        ne = jnp.stack(need_expand) if nsched else jnp.zeros(0, jnp.int32)
        nc = jnp.stack(need_compact) if nsched else jnp.zeros(0, jnp.int32)
        if fvalid[0] is not None:  # mask-mode filters fold in only here
            valid = valid & fvalid[0]
        if agg == "count":
            return jnp.sum(jnp.where(valid, mult, 0)), ne, nc
        # lanes that went through a weighted trie's probe path can survive
        # with mult 0 (pad groups weigh nothing); they are not output rows
        valid = valid & (mult > 0)
        return bound, valid, mult, ne, nc

    return run


def overflows(cap_plan, need_expand, need_compact):
    """Per-node overflow bits from the executor's reported needs and the
    capacity plan the run used: (ovf_expand, ovf_compact) bool arrays."""
    ne = np.asarray(need_expand)
    nc = np.asarray(need_compact)
    caps = np.asarray(cap_plan.capacities, np.int64)
    cts = np.array(
        [np.iinfo(np.int64).max if c is None else c for c in cap_plan.compact_to], np.int64
    )
    return ne > caps, nc > cts


def make_chain_executor(
    stages,
    cap_plans,
    *,
    impl: str = "jnp",
    budget: int = 32,
    agg: str | None = "count",
    filter_vars: tuple[str, ...] = (),
    filter_kill: bool = True,
):
    """One on-device program for a whole bushy plan (Sec 2.2 stages).

    stages: ((name, FreeJoinPlan), ...) with the root stage last — each plan
    may reference earlier stages' names as relation aliases; cap_plans: one
    CapacityPlan per stage (schedule riding along). Every non-root stage
    runs its make_executor with agg=None, its output columns stay on device
    as a padded buffer (invalid lanes stamped PAD_KEY, multiplicity 0), and
    the next stage builds a weighted StaticTrie straight from that buffer —
    no host round-trip, no eager engine. Returns
        run(rel_data) -> (root outputs..., need_expand_t, need_compact_t)
    where rel_data holds the *base* relations only — prebuilt StaticTries
    or raw column dicts per alias, exactly as make_executor accepts — and
    the need vectors are per-stage tuples (one (num_nodes,) int32 vector
    each, stage order). Stage-output tries are always built in-graph: they
    are weighted buffers of this one run and never cacheable.

    filter_vars names equality-selected vars (plan-template constants, see
    make_executor): run gains a `filter_consts` int32 vector in
    filter_vars order, and each var's comparison runs in the FIRST stage
    that binds it — filtered rows carry mult 0 into downstream weighted
    tries, so later stages never re-check. filter_kill picks the
    comparison's disposition (see make_executor); in mask mode a non-root
    stage's terminal fold still stamps filter-dead rows mult-0, so later
    stages of a batched chain run per-lane — single-stage plans are the
    fully-shared fast path."""
    assert len(stages) == len(cap_plans) >= 1, "one capacity plan per stage"
    filter_vars = tuple(filter_vars)
    unassigned = dict((v, i) for i, v in enumerate(filter_vars))
    fns = []
    for i, ((_name, plan), cp) in enumerate(zip(stages, cap_plans)):
        stage_filters = tuple(
            (v, unassigned.pop(v)) for v in tuple(plan.query.variables) if v in unassigned
        )
        fns.append(
            make_executor(
                plan,
                cp.capacities,
                compact_to=cp.compact_to,
                compact_probe=getattr(cp, "compact_probe", ()),
                impl=impl,
                budget=budget,
                agg=agg if i == len(stages) - 1 else None,
                schedule=cp.schedule,
                filters=stage_filters,
                filter_kill=filter_kill,
            )
        )
    assert not unassigned, f"filter vars not bound by any stage: {sorted(unassigned)}"

    def run(rel_data: dict[str, object], filter_consts: jnp.ndarray | None = None):
        cols = dict(rel_data)
        stage_mults: dict[str, jnp.ndarray] = {}
        nes, ncs = [], []
        for (name, plan), fn in zip(stages[:-1], fns[:-1]):
            bound, valid, mult, ne, nc = fn(cols, stage_mults, filter_consts)
            head = plan.query.head
            cols[name] = {v: jnp.where(valid, bound[v], PAD_KEY) for v in head}
            stage_mults[name] = jnp.where(valid, mult, 0).astype(jnp.int32)
            nes.append(ne)
            ncs.append(nc)
        out = fns[-1](cols, stage_mults, filter_consts)
        nes.append(out[-2])
        ncs.append(out[-1])
        return out[:-2] + (tuple(nes), tuple(ncs))

    return run


def make_count_fn(
    plan: FreeJoinPlan,
    capacities: list[int],
    impl: str = "jnp",
    budget: int = 32,
    *,
    schedule: StaticSchedule | None = None,
):
    """Original count-only surface: fn(rel_cols) -> (count, overflowed).
    One scalar overflow flag; no compaction. Kept for benchmarks and dry
    runs — the SPMD driver (core/distributed.py) uses make_executor's need
    vectors directly so its retry loop can grow the offending node."""
    if schedule is None:
        schedule = _static_schedule(plan)
    inner = make_executor(
        plan, capacities, impl=impl, budget=budget, agg="count", schedule=schedule
    )
    caps = jnp.asarray(
        tuple(int(c) for c in capacities[: len(schedule)]) or (0,), jnp.int32
    )

    def run(rel_cols):
        count, ne, nc = inner(rel_cols)
        return count, (ne > caps[: ne.shape[0]]).any()

    return run


def _needed_later_static(plan: FreeJoinPlan, k: int, probes, agg: str | None = "count") -> set[str]:
    need: set[str] = set()
    for sa in probes:
        need |= set(sa.vars)
    for node in plan.nodes[k + 1 :]:
        for sa in node:
            need |= set(sa.vars)
    if agg != "count":
        need |= set(plan.query.head)
    return need


def count_query(
    plan: FreeJoinPlan,
    relations,
    capacities: list[int],
    impl: str = "jnp",
    jit: bool = True,
    budget: int = 32,
):
    """Convenience: run the compiled COUNT on host numpy relations."""
    rel_cols = relations_to_cols(plan, relations)
    fn = make_count_fn(plan, capacities, impl, budget)
    if jit:
        fn = jax.jit(fn)
    count, overflow = fn(rel_cols)
    return int(count), bool(overflow)


def relations_to_cols(plan: FreeJoinPlan, relations) -> dict[str, dict[str, jnp.ndarray]]:
    """Device int32 columns for every alias the plan touches."""
    return stage_relations_to_cols((("__root", plan),), relations)


def _base_aliases(stages) -> set[str]:
    """Every relation alias a stage chain reads from the caller — stage
    names are produced on device by the chain executor, never read."""
    names = {name for name, _ in stages}
    return {sa.alias for _, plan in stages for node in plan.nodes for sa in node} - names


def stage_relations_to_cols(stages, relations) -> dict[str, dict[str, jnp.ndarray]]:
    """Device int32 columns for every *base* alias a stage chain touches."""
    return {
        a: {v: jnp.asarray(relations[a].columns[v], jnp.int32) for v in relations[a].schema}
        for a in _base_aliases(stages)
    }


class AdaptiveExecutor:
    """Overflow-retrying driver around the chained executor (see module
    docstring).

    Accepts a single FreeJoinPlan + CapacityPlan (the classic one-stage
    surface) or a full stage chain — ((name, plan), ...) root last — with a
    ChainCapacityPlan; either way the whole program runs as ONE compiled
    call. If any stage's node reports a need above its capacity, jumps
    exactly that node's capacity (or compaction target) to the reported
    need and re-runs — one retry per offending node, not a doubling ladder.
    Compiled executors are cached per capacity-vector chain and the grown
    plan replaces the initial one, so a stream of similar queries pays the
    retry + recompile once and then runs overflow-free.

    run_relations is the warm serving surface: device uploads come from the
    per-relation registry and base tries from the cross-call TRIE_CACHE, so
    repeated calls over the same relations — and every overflow/tighten
    re-run — pay probe cost only. Calling the executor directly with raw
    column dicts keeps the cold (build-in-graph) behavior.

    Serving extensions (the multi-tenant path, see serve/join_engine.py):

    * filter_vars — equality selections whose constants are runtime inputs
      (plan templates): __call__ takes a `filter_consts` int32 vector in
      filter_vars order, and one compiled executor serves every constant.
    * batch=B — the whole chain is vmapped over filter_consts, so ONE
      device dispatch runs B queries of the template against the SAME
      shared tries: filter_consts becomes (B, F), counts come back (B,),
      and need vectors come back per lane. Overflow growth uses the
      per-node max across lanes (the chain's static shapes are shared).
    * max_capacity — per-node growth quota: a need that would grow any
      node past it raises capacity.CapacityQuotaError naming the offending
      batch lane instead of recompiling the shared executor, so admission
      control can reject exactly that request.
    """

    def __init__(
        self,
        plan,
        cap_plan,
        *,
        impl: str = "jnp",
        budget: int = 32,
        agg: str | None = "count",
        jit: bool = True,
        max_retries: int = 12,
        tighten: bool = False,
        filter_vars: tuple[str, ...] = (),
        batch: int | None = None,
        max_capacity: int | None = None,
    ):
        from repro.core.capacity import ChainCapacityPlan  # deferred: no cycle

        stages = (
            (("__root", plan),)
            if isinstance(plan, FreeJoinPlan)
            else tuple((name, p) for name, p in plan)
        )
        chain = (
            cap_plan
            if isinstance(cap_plan, ChainCapacityPlan)
            else ChainCapacityPlan(names=tuple(n for n, _ in stages), stages=(cap_plan,))
        )
        assert len(chain.stages) == len(stages), "one capacity plan per stage"
        # reuse the schedules the planner already computed, if they rode along
        chain = chain.with_schedules(
            tuple(
                cp.schedule if cp.schedule is not None else _static_schedule(p)
                for cp, (_n, p) in zip(chain.stages, stages)
            )
        )
        for _name, p in stages:
            p.validate()
        self.stages = stages
        self._single = len(stages) == 1
        self.plan = stages[-1][1]  # the root stage plan
        self.cap_plan = chain.stages[0] if self._single else chain
        self.schedules = tuple(cp.schedule for cp in chain.stages)
        self.schedule = self.schedules[-1]
        self.impl = impl
        self.budget = budget
        self.agg = agg
        self.jit = jit
        self.max_retries = max_retries
        self.tighten = tighten
        self.filter_vars = tuple(filter_vars)
        self.batch = batch
        self.max_capacity = max_capacity
        assert batch is None or self.filter_vars, (
            "batched execution varies only the constant vector per lane; "
            "a template with no filters should run once, unbatched"
        )
        self.retries = 0  # total overflow re-runs across calls
        self.reshapes = 0  # tightening re-runs across calls
        self.calls = 0  # top-level call chains issued (retries excluded)
        self._cache: dict[tuple, object] = {}
        # memory-governor token, set by api._govern_runner when this runner
        # is cached: growth re-accounts against the budget and sheds
        # (MemoryBudgetError -> the serving ladder) instead of allocating
        self._govern_token = None
        self._last_needs = None  # per-stage measured expansion needs (lane counts)
        self._feedback_specs = None  # lazily-derived per-node prefix specs
        # base alias -> its level layout (for cross-call trie reuse); an
        # alias read under two different layouts falls back to raw columns
        base = _base_aliases(stages)
        self._alias_lops: dict[str, _LevelOps | None] = {}
        for sched in self.schedules:
            for a, lo in sched.level_ops.items():
                if a not in base:
                    continue
                if a in self._alias_lops and self._alias_lops[a] != lo:
                    self._alias_lops[a] = None
                else:
                    self._alias_lops.setdefault(a, lo)

    @property
    def compiles(self) -> int:
        return len(self._cache)

    def _as_chain(self, cp):
        from repro.core.capacity import ChainCapacityPlan  # deferred: no cycle

        if isinstance(cp, ChainCapacityPlan):
            return cp
        return ChainCapacityPlan(names=tuple(n for n, _ in self.stages), stages=(cp,))

    def frontier_nbytes(self, cap_plan=None) -> int:
        """Accounting model of this runner's frontier footprint: per stage,
        cells x 4 bytes x (bound vars + valid + mult), plus per-lane mask
        columns for batched (mask-mode) runners. The governor's currency
        for runner-cache entries and adaptive growth."""
        chain = self._as_chain(self.cap_plan if cap_plan is None else cap_plan)
        total = 0
        for (_name, p), cp in zip(self.stages, chain.stages):
            width = len(tuple(p.query.variables)) + 2
            total += cp.cells() * 4 * width
            if self.batch:
                total += cp.cells() * 4 * self.batch
        return total

    def _fn(self, chain):
        key = chain.key()
        if key not in self._cache:
            faults.fire("compile")
            fn = make_chain_executor(
                self.stages,
                chain.stages,
                impl=self.impl,
                budget=self.budget,
                agg=self.agg,
                filter_vars=self.filter_vars,
                # batched runs use mask-mode filters so the frontier layout
                # is shared across lanes; single-query runs keep kill mode
                # (lane death feeds compaction, a selective constant is
                # genuinely cheaper)
                filter_kill=self.batch is None,
            )
            if self.batch is not None:
                # one dispatch for the whole template batch: tries are
                # broadcast (in_axes=None), only the constant vector is
                # mapped — pre-filter work stays unbatched inside vmap
                fn = jax.vmap(fn, in_axes=(None, 0))
            self._cache[key] = jax.jit(fn) if self.jit else fn
        return self._cache[key]

    def _reduced(self, need):
        """Per-node need vector of a (possibly per-lane) reported need:
        batched runs report (B, n); the chain's static shapes are shared,
        so growth follows the max over lanes."""
        need = np.asarray(need)
        return need.max(axis=0) if need.ndim == 2 else need

    def _check_quota(self, chain, s: int, i: int, need: int, per_lane) -> None:
        from repro.core.capacity import CapacityQuotaError, _round_block

        if self.max_capacity is None:
            return
        cp = chain.stages[s]
        target = max(2 * cp.capacities[i], _round_block(int(need), cp.block))
        if target <= self.max_capacity:
            return
        lane = None
        if per_lane.ndim == 2:
            lane = int(np.argmax(per_lane[:, i]))
        raise CapacityQuotaError(s, i, int(need), self.max_capacity, lane=lane)

    def __call__(self, rel_data: dict[str, object], filter_consts=None):
        """agg="count" -> count scalar; agg=None -> (bound, valid, mult).
        rel_data values are prebuilt StaticTries and/or raw column dicts
        (see make_executor). filter_consts: (F,) int32 in filter_vars
        order — or (batch, F) for a batched runner, which returns (B,)
        counts (agg="count") or per-lane (bound, valid, mult)."""
        from repro.core.capacity import _round_block  # deferred: no cycle

        if self.filter_vars:
            assert filter_consts is not None, "this runner's template has filters"
            # explicit h2d (device_put), not jnp.asarray: the warm serving
            # step must hold under jax.transfer_guard("disallow") — every
            # remaining transfer in this driver is deliberate and visible
            filter_consts = (
                filter_consts.astype(jnp.int32)
                if isinstance(filter_consts, jax.Array)
                else jax.device_put(np.asarray(filter_consts, np.int32))
            )
            want = (self.batch, len(self.filter_vars)) if self.batch else (
                len(self.filter_vars),
            )
            assert filter_consts.shape == want, (filter_consts.shape, want)
        chain = self._as_chain(self.cap_plan)
        self.calls += 1
        tightened = False
        faults.fire("overflow", batch=self.batch, max_capacity=self.max_capacity)
        for _ in range(self.max_retries + 1):
            fn = self._fn(chain)
            faults.fire("dispatch")
            out = fn(rel_data, filter_consts) if self.filter_vars else fn(rel_data)
            # ONE explicit d2h for the control plane: the per-stage need
            # vectors drive host-side overflow/tighten decisions. Results
            # stay on device until the caller reads them.
            needs_e, needs_c = jax.device_get((out[-2], out[-1]))
            grown = chain
            for s, (cp, ne_l, nc_l) in enumerate(zip(chain.stages, needs_e, needs_c)):
                ne, nc = self._reduced(ne_l), self._reduced(nc_l)
                oe, oc = overflows(cp, ne, nc)
                for i in np.flatnonzero(oc):
                    grown = grown.grow_to(s, int(i), int(nc[i]), compaction=True)
                for i in np.flatnonzero(oe):
                    self._check_quota(chain, s, int(i), int(ne[i]), np.asarray(ne_l))
                    grown = grown.grow_to(s, int(i), int(ne[i]))
            if grown is not chain:
                if self._govern_token is not None:
                    # growth must fit the device-memory budget: a shed here
                    # raises MemoryBudgetError into the degradation ladder
                    # instead of growing past what the device can hold
                    membudget.GOVERNOR.account(
                        self._govern_token, self.frontier_nbytes(grown)
                    )
                chain = grown
                self.retries += 1
                continue
            if self.tighten and not tightened:
                # success with measured needs in hand: shrink any buffer
                # that ran >2x oversized and re-run once at the tight
                # shapes, so steady state pays for measured frontiers, not
                # for planning estimates (the planner only has to be right
                # on average; the measurement is exact)
                shrunk = chain
                for s, (ne, nc) in enumerate(zip(needs_e, needs_c)):
                    ne, nc = self._reduced(ne), self._reduced(nc)
                    for i in range(len(ne)):
                        cp = shrunk.stages[s]
                        if cp.capacities[i] > 2 * _round_block(int(ne[i]), cp.block):
                            shrunk = shrunk.shrink_to(s, i, int(ne[i]))
                        ct = shrunk.stages[s].compact_to[i]
                        if ct is not None and ct > 2 * _round_block(int(nc[i]), cp.block):
                            shrunk = shrunk.shrink_to(s, i, int(nc[i]), compaction=True)
                if shrunk is not chain:
                    chain = shrunk
                    tightened = True
                    self.reshapes += 1
                    continue
            # steady state: keep the grown/tightened plan
            self.cap_plan = chain.stages[0] if self._single else chain
            if self._govern_token is not None:
                membudget.GOVERNOR.account(
                    self._govern_token, self.frontier_nbytes(chain)
                )
            # stash the measured per-node expansion needs: exact frontier
            # lane counts, the optimizer's measured-cardinality feedback
            self._last_needs = tuple(self._reduced(ne) for ne in needs_e)
            result = out[:-2]
            return result[0] if self.agg == "count" else result
        raise RuntimeError(
            f"frontier overflow persists after {self.max_retries} retries: {chain}"
        )

    def _node_feedback_specs(self):
        """Per stage, per executed node: the (alias, consumed-vars) multiset
        whose joined cardinality that node's need_expand measures — or None
        when the measurement is not a joined-prefix size. Two exclusions:
        a cover that re-binds an already-bound variable (the executor
        semijoins AFTER expanding, so the count is pre-equate), and a stage
        alias whose consumed prefix is not the stage's full head (device-
        only output, no base-relation equivalent). A fully-consumed stage
        alias substitutes its own atoms' full specs, recursively, so every
        recorded spec names only base relations."""
        names = {n for n, _ in self.stages}
        full_specs: dict[str, tuple | None] = {}
        heads = {name: frozenset(p.query.head) for name, p in self.stages}
        out = []
        for (name, plan), sched in zip(self.stages, self.schedules):
            aliases = {sa.alias for node in plan.nodes for sa in node}
            prefix: dict[str, tuple[str, ...]] = {a: () for a in aliases}
            bound: set[str] = set()
            per_node = []
            for _k, cover, probes in sched.entries:
                rebinds = bool(set(cover.vars) & bound)
                prefix[cover.alias] = prefix[cover.alias] + tuple(cover.vars)
                bound |= set(cover.vars)
                spec: list | None = None if rebinds else []
                if spec is not None:
                    for a, vs in prefix.items():
                        if not vs:
                            continue
                        if a in names or a.startswith("__stage"):
                            # "__stage" but not in names: the hybrid path's
                            # per-call host materialization — never recorded
                            sub = (
                                full_specs.get(a)
                                if frozenset(vs) == heads.get(a)
                                else None
                            )
                            if sub is None:
                                spec = None
                                break
                            spec.extend(sub)
                        else:
                            spec.append((a, frozenset(vs)))
                per_node.append(tuple(spec) if spec else None)
                for sa in probes:
                    prefix[sa.alias] = prefix[sa.alias] + tuple(sa.vars)
                    bound |= set(sa.vars)
            out.append(tuple(per_node))
            fs: list | None = []
            for a in plan.query.atoms:
                if a.alias in names or a.alias.startswith("__stage"):
                    sub = full_specs.get(a.alias)
                    if sub is None:
                        fs = None
                        break
                    fs.extend(sub)
                else:
                    fs.append((a.alias, frozenset(a.vars)))
            full_specs[name] = tuple(fs) if fs else None
        return tuple(out)

    def _record_feedback(self, relations) -> None:
        """Persist the last call's measured expansion needs into the
        process-wide measured-cardinality store (relcache.FEEDBACK). Only
        meaningful measurements land: kill-mode filtered runs are skipped
        by the caller (lane counts depend on the constants; mask-mode
        batched runs keep the unfiltered layout and are safe), and nodes
        with no recordable prefix spec or a zero need (the factorized-count
        shortcut never expands) are skipped here."""
        from repro.core import relcache

        if self._last_needs is None:
            return
        if self._feedback_specs is None:
            self._feedback_specs = self._node_feedback_specs()
        for per_node, needs in zip(self._feedback_specs, self._last_needs):
            for spec, n in zip(per_node, np.asarray(needs)):
                if spec is None or int(n) <= 0:
                    continue
                relcache.FEEDBACK.record(
                    [(relations[a], vs) for a, vs in spec], int(n)
                )

    def run_relations(self, relations, *, reuse_tries: bool = True, filter_consts=None):
        """Convenience: host relations in, host results out — the warm
        path. Device columns come from the per-relation registry (uploaded
        once per column object) and base tries from the cross-call
        TRIE_CACHE, so a stream of calls over the same relations performs
        zero builds after the first. reuse_tries=False bypasses the trie
        cache and rebuilds in-graph every call (the cold baseline the
        benchmarks time). A batched runner returns the per-lane results:
        a (B,) int64 count vector for agg="count", else a list of
        (cols, mult) pairs, one per lane.

        Successful runs feed the optimizer's measured-cardinality loop:
        each node's exact frontier need is recorded against the relation
        objects it joined (see _record_feedback), except kill-mode filtered
        runs, whose lane counts depend on the selection constants."""
        data = {}
        for a in sorted(_base_aliases(self.stages)):
            rel = relations[a]
            if reuse_tries:
                lo = self._alias_lops.get(a)
                if lo is not None:
                    data[a] = TRIE_CACHE.get(
                        rel, device_columns(rel), lo, impl=self.impl, budget=self.budget
                    )
                    continue
            # raw-column (in-graph build) path: a tombstoned relation must
            # contribute its live rows only, so feed the per-version live
            # snapshot — an unweighted in-graph build has no mult to kill
            # the dead rows with
            data[a] = device_columns(relcache.live_relation(rel))
        out = self(data, filter_consts)
        if not self.filter_vars or self.batch is not None:
            self._record_feedback(relations)
        if self.agg == "count":
            # explicit d2h: the count read-back is the warm path's only
            # result transfer (see the transfer-guard regression test)
            host = jax.device_get(out)
            return np.asarray(host, np.int64) if self.batch else int(host)
        if self.batch:
            bound, valid, mult = out
            return [
                materialize_compiled(
                    {v: a[b] for v, a in bound.items()}, valid[b], mult[b]
                )
                for b in range(self.batch)
            ]
        return materialize_compiled(*out)


def materialize_compiled(bound, valid, mult):
    """Strip padding lanes from an agg=None result: returns (cols, mult) as
    host numpy arrays over live rows only (the eager engine's contract —
    expand duplicate multiplicities with engine.materialize)."""
    bound, valid, mult = jax.device_get((bound, valid, mult))
    v = np.asarray(valid)
    cols = {name: np.asarray(a)[v].astype(np.int64) for name, a in bound.items()}
    return cols, np.asarray(mult)[v].astype(np.int64)
