"""Static-shape Free Join: the jit/shard_map-able TPU path.

The eager engine (engine.py) is the paper-faithful reproduction; this module
re-expresses the same plan execution with fully static shapes so it lowers
under jit on a device mesh:

* Tries are built by one lexsort over the consumed level vars + boundary
  flags + segment sums — all arrays keep the base relation's static length N
  (group counts are dynamic *values*, never dynamic *shapes*). COLT's
  "build only what the plan consumes" survives statically: only levels the
  plan probes get tables, and a relation that is only iterated at a single
  level skips the build entirely.
* The frontier is a capacity-bounded buffer with a valid mask. Iteration is
  `expand_counted` (prefix-sum + binary-search addressing — the csr_expand
  kernel); probing is the hash_probe kernel. When the planner predicts a
  node's probes kill most lanes, the frontier is *compacted* (prefix-sum
  scatter, kernels/compact.py) into a smaller buffer so later nodes pay for
  live rows, not for the largest buffer ever allocated.
* Bag semantics via a mult column; factorized counting is decided statically
  from the plan (cover at its last level whose vars are never used again).

The shared-driver contract (one planning pass serves the local *and* the
distributed compiled paths — api.compiled_free_join and
distributed.spmd_count are both thin drivers over the same stack):

* The driver builds one optimizer.Stats cache (one np.unique per referenced
  column) and one StaticSchedule (one plan walk) per query, and threads
  them through optimize -> capacity.plan_capacities ->
  optimizer.estimate_prefixes -> make_executor. The schedule rides on the
  CapacityPlan so every later executor build reuses it.
* capacity.plan_capacities derives a CapacityPlan — per-node expansion
  capacities plus compaction targets — from the per-prefix cardinality
  estimates capped by the AGM bound. No manual capacities. The distributed
  driver feeds it per-shard statistics instead (sizes and distinct counts
  shrunk by the hypercube shares); nothing else changes.
* make_executor builds the jit-able executor for one capacity vector.
  Buffer pressure is reported per node as *required totals*, never silently
  and never as mere bits: agg="count" returns (count, need_expand,
  need_compact); agg=None returns (bound columns padded to the final
  capacity, valid mask, mult, need_expand, need_compact). need_expand[i] is
  the lane count node i's expansion actually required, need_compact[i] the
  live lane count at its compact point; node i overflowed iff the need
  exceeds its capacity (resp. compaction target), and the need tells the
  retry loop the exact capacity to jump to.
* AdaptiveExecutor wraps make_executor in an overflow-retry loop: on
  overflow it grows exactly the offending node's capacity (or compaction
  target) straight to the reported need (CapacityPlan.grow_to — one retry,
  not a geometric ladder) and re-runs, caching one compiled executor per
  capacity vector — steady-state traffic never recompiles and never
  overflows, because the grown plan is remembered.
* Zero-row relations are handled natively: an empty relation builds a
  StaticTrie whose every frontier expansion yields zero live lanes and
  whose probes match nothing, so drivers need no host-side empty gate.

make_count_fn/count_query keep the original count-only surface (manual
capacities, scalar overflow bit) for benchmarks and dry runs;
distributed.spmd_count uses make_executor directly and runs the grow/retry
loop *outside* the shard_map collective.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import FreeJoinPlan
from repro.kernels import ops


@dataclass(frozen=True)
class _LevelOps:
    """Static decisions for one atom: which levels are probed/iterated."""

    levels: tuple[tuple[str, ...], ...]
    probed: tuple[bool, ...]  # per level: consumed by probe?


@dataclass(frozen=True)
class StaticSchedule:
    """One static walk of a plan, computed once per query and threaded
    through the whole driver stack (planner, estimator, executor builds).
    entries[i] = (node index, cover subatom, probe subatoms); level_ops maps
    alias -> per-level probe/iterate decisions."""

    entries: tuple
    level_ops: dict

    def __len__(self):
        return len(self.entries)

    def __iter__(self):
        return iter(self.entries)


def _static_schedule(plan: FreeJoinPlan) -> StaticSchedule:
    """Walk the plan once, statically: per node pick the cover (first listed
    — plans arrive factored), mark each atom level probe/iterate."""
    parts = plan.partitions()
    consumed: dict[str, int] = {a: 0 for a in parts}
    probed: dict[str, list[bool]] = {a: [False] * len(parts[a]) for a in parts}
    schedule = []
    for k, node in enumerate(plan.nodes):
        subs = [sa for sa in node if sa.vars]
        if not subs:
            continue
        covers = [sa for sa in plan.covers(k) if sa.vars and any(sa is s for s in subs)]
        cover = covers[0]
        probes = tuple(sa for sa in subs if sa is not cover)
        schedule.append((k, cover, probes))
        for sa in probes:
            probed[sa.alias][consumed[sa.alias]] = True
            consumed[sa.alias] += 1
        consumed[cover.alias] += 1
    level_ops = {a: _LevelOps(tuple(parts[a]), tuple(probed[a])) for a in parts}
    return StaticSchedule(entries=tuple(schedule), level_ops=level_ops)


class StaticTrie:
    """Sort-based trie with static shapes (see module docstring)."""

    def __init__(self, cols: dict[str, jnp.ndarray], lops: _LevelOps, impl: str, budget: int = 32):
        self.impl = impl
        self.L = len(lops.levels)
        self.levels = lops.levels
        some = next(iter(cols.values()))
        self.empty = some.shape[0] == 0
        if self.empty:
            # zero-row relation: keep one sentinel row so every downstream
            # gather has a real operand; iter_counts/rows_under/probe below
            # force zero live lanes, so the sentinel is never observable
            cols = {k: jnp.full(1, -1, jnp.int32) for k in cols}
            some = next(iter(cols.values()))
        n = some.shape[0]
        self.n = n
        self.cols = {k: v.astype(jnp.int32) for k, v in cols.items()}
        self.trivial = self.L == 1 and not lops.probed[0]
        if self.trivial:  # pure cover: iterate the base table, zero build
            return
        all_vars = [v for lv in lops.levels for v in lv]
        order = jnp.lexsort(tuple(self.cols[v] for v in reversed(all_vars)))
        self.order = order.astype(jnp.int32)
        sc = {v: self.cols[v][order] for v in all_vars}
        self.sorted_cols = sc
        idx = jnp.arange(n, dtype=jnp.int32)
        # depth-d group ids for d = 0..L, flags for d = 1..L
        self.g = [jnp.zeros(n, jnp.int32)]  # g[0] = root
        self.kpos = [jnp.zeros(1, jnp.int32)]  # first position of each group
        flag = jnp.zeros(n, dtype=bool)
        self.child_base, self.child_counts, self.row_count, self.tables = [], [], [], []
        for d, lv in enumerate(lops.levels):
            diff = jnp.zeros(n, dtype=bool).at[0].set(True)
            for v in lv:
                diff = diff.at[1:].set(diff[1:] | (sc[v][1:] != sc[v][:-1]))
            flag = flag | diff
            flag = flag.at[0].set(True)
            gd1 = (jnp.cumsum(flag.astype(jnp.int32)) - 1).astype(jnp.int32)  # g[d+1]
            # children of each depth-d group (counts over depth-(d+1) firsts)
            ccnt = jax.ops.segment_sum(flag.astype(jnp.int32), self.g[d], num_segments=n)
            cbase = jnp.cumsum(ccnt) - ccnt
            kp = jnp.zeros(n + 1, jnp.int32).at[jnp.where(flag, gd1, n)].set(idx, mode="drop")
            rcnt = jax.ops.segment_sum(jnp.ones(n, jnp.int32), gd1, num_segments=n)
            self.g.append(gd1)
            self.kpos.append(kp[:n])
            self.child_base.append(cbase.astype(jnp.int32))
            self.child_counts.append(ccnt.astype(jnp.int32))
            self.row_count.append(rcnt)
            if lops.probed[d]:
                parent = jnp.where(flag, self.g[d], -idx - 2)  # sentinels unique
                key_rows = jnp.stack([parent] + [jnp.where(flag, sc[v], 0) for v in lv], axis=1)
                self.tables.append(ops.build_table(key_rows, budget=budget))
            else:
                self.tables.append(None)

    # depth-d group sizes in rows (for factorized count / multiplicity)
    def rows_under(self, d: int, gids: jnp.ndarray) -> jnp.ndarray:
        if self.empty:
            return jnp.zeros(gids.shape, jnp.int32)
        if self.trivial or d == 0:
            return jnp.full(gids.shape, self.n, jnp.int32)
        return self.row_count[d - 1][gids]

    def probe(self, d: int, gids, key_cols):
        if self.empty:  # nothing to match: kill every probing lane
            return jnp.full(gids.shape, -1, jnp.int32)
        q = jnp.stack([gids.astype(jnp.int32)] + [c.astype(jnp.int32) for c in key_cols], axis=1)
        p = ops.probe(self.tables[d], q, impl=self.impl)
        child = self.g[d + 1][jnp.clip(p, 0, self.n - 1)]
        return jnp.where(p >= 0, child, -1)

    def iter_counts(self, d: int, gids, last: bool):
        """(base, counts) for expand_counted at level d from groups `gids`.
        last=True enumerates rows; otherwise enumerates child groups."""
        z = jnp.zeros(gids.shape, jnp.int32)
        if self.empty:  # every expansion yields zero live lanes
            return z, z
        if self.trivial:
            return z, jnp.full(gids.shape, self.n, jnp.int32)
        if last:
            base = self.kpos[d][jnp.clip(gids, 0, self.n - 1)] if d > 0 else jnp.zeros(gids.shape, jnp.int32)
            counts = self.rows_under(d, gids)
            return base, counts
        return self.child_base[d][gids], self.child_counts[d][gids]

    def bind_iter(self, d: int, members, last: bool):
        """Column values bound by iterating; members from expand_counted.
        Returns (cols list in level-var order, new_gids or None)."""
        lv = self.levels[d]
        if self.trivial:
            return [self.cols[v][members] for v in lv], None
        if last:
            rows = self.order[members]
            return [self.cols[v][rows] for v in lv], self.g[d + 1][members]
        kp = self.kpos[d + 1][members]
        return [self.sorted_cols[v][kp] for v in lv], members


def make_executor(
    plan: FreeJoinPlan,
    capacities,
    *,
    compact_to=None,
    compact_probe=None,
    impl: str = "jnp",
    budget: int = 32,
    agg: str | None = "count",
    schedule: StaticSchedule | None = None,
):
    """Build a jit-able executor for `plan` (see module docstring).

    capacities: one static expansion capacity per executed node; compact_to:
    optional per-node compaction target (None = keep the buffer);
    compact_probe: per node, how many probes run before compacting (default
    all — compact after the node; smaller values compact mid-node so the
    remaining probes run at the squeezed width); schedule: the query's
    StaticSchedule if the driver already computed it (None = walk the plan
    here). Returns fn(rel_cols: {alias: {var: (N,) int32}}) ->
      agg="count":  (count, need_expand, need_compact)
      agg=None:     (bound, valid, mult, need_expand, need_compact)
    where need_expand/need_compact are (num_executed_nodes,) int32 vectors
    of required totals: need_expand[i] is the lane count node i's expansion
    produced, need_compact[i] the live count at its compact point (0 when
    the node doesn't expand/compact). Node i overflowed iff
    need_expand[i] > capacities[i] (resp. need_compact[i] > compact_to[i]);
    the need is the exact capacity the adaptive runner should jump to.
    """
    plan.validate()
    if schedule is None:
        schedule = _static_schedule(plan)
    level_ops = schedule.level_ops
    schedule = schedule.entries
    nsched = len(schedule)
    capacities = tuple(int(c) for c in capacities[:nsched])
    assert len(capacities) == nsched, "one capacity per executed node"
    compact_to = tuple(compact_to[:nsched]) if compact_to is not None else (None,) * nsched
    assert len(compact_to) == nsched, "one compaction target per executed node"
    compact_probe = (
        tuple(compact_probe[:nsched])
        if compact_probe
        else tuple(len(probes) for _, _, probes in schedule)
    )
    assert len(compact_probe) == nsched, "one compact point per executed node"

    def run(rel_cols: dict[str, dict[str, jnp.ndarray]]):
        tries = {a: StaticTrie(rel_cols[a], level_ops[a], impl, budget) for a in level_ops}
        depth = {a: 0 for a in level_ops}
        # frontier
        cap = 1
        valid = jnp.ones(1, dtype=bool)
        mult = jnp.ones(1, jnp.int32)  # int64 needs x64; counts < 2^31 here
        bound: dict[str, jnp.ndarray] = {}
        gid: dict[str, jnp.ndarray] = {}
        need_expand = [jnp.zeros((), jnp.int32) for _ in range(nsched)]
        need_compact = [jnp.zeros((), jnp.int32) for _ in range(nsched)]

        def squeeze(bound, gid, mult, valid, cap, c_compact, i):
            """Pack the valid lanes into a fresh c_compact-wide frontier."""
            src, live = ops.compact_indices(valid, c_compact, impl=impl)
            need_compact[i] = live
            srcc = jnp.clip(src, 0, cap - 1)
            bound = {v: a[srcc] for v, a in bound.items()}
            gid = {a: arr[srcc] for a, arr in gid.items()}
            mult = mult[srcc]
            valid = jnp.arange(c_compact, dtype=jnp.int32) < live
            return bound, gid, mult, valid, c_compact

        for i, ((k, cover, probes), c_next, c_compact, cp_idx) in enumerate(
            zip(schedule, capacities, compact_to, compact_probe)
        ):
            t = tries[cover.alias]
            d = depth[cover.alias]
            g = gid.get(cover.alias, jnp.zeros(cap, jnp.int32))
            last = d == t.L - 1
            needed = _needed_later_static(plan, k, probes, agg)
            if agg == "count" and not (set(cover.vars) & needed) and last and not (
                set(cover.vars) & set(bound)
            ):
                # factorized count (static decision)
                mult = mult * jnp.where(valid, t.rows_under(d, g), 1).astype(jnp.int32)
                gid.pop(cover.alias, None)
                depth[cover.alias] = t.L
            else:
                base, counts = t.iter_counts(d, g, last)
                counts = jnp.where(valid, counts, 0)
                fr, member, vnew, total = ops.expand_counted(base, counts, c_next, impl=impl)
                need_expand[i] = total
                frc = jnp.clip(fr, 0, cap - 1)
                memc = jnp.clip(member, 0, max(t.n - 1, 0))
                bound = {v: a[frc] for v, a in bound.items()}
                gid = {a: arr[frc] for a, arr in gid.items()}
                mult = mult[frc]
                valid = vnew
                cap = c_next
                cols, new_g = t.bind_iter(d, memc, last)
                for v, cvals in zip(cover.vars, cols):
                    if v in bound:  # semijoin on re-bound vars
                        valid = valid & (bound[v] == cvals)
                    else:
                        bound[v] = cvals
                depth[cover.alias] = d + 1
                if new_g is None or depth[cover.alias] == t.L:
                    # last-level iteration enumerates physical rows, so bag
                    # multiplicity is already accounted for — no mult here.
                    gid.pop(cover.alias, None)
                else:
                    gid[cover.alias] = new_g
            compacted = False
            for j, sa in enumerate(probes):
                tp = tries[sa.alias]
                dp = depth[sa.alias]
                gp = gid.get(sa.alias, jnp.zeros(cap, jnp.int32))
                keys = [bound[v] for v in sa.vars]
                child = tp.probe(dp, jnp.where(valid, gp, -1), keys)
                valid = valid & (child >= 0)
                childc = jnp.clip(child, 0, max(tp.n - 1, 0))
                depth[sa.alias] = dp + 1
                if depth[sa.alias] == tp.L:
                    mult = mult * jnp.where(valid, tp.rows_under(tp.L, childc), 1).astype(jnp.int32)
                    gid.pop(sa.alias, None)
                else:
                    gid[sa.alias] = childc
                if c_compact is not None and not compacted and j + 1 >= cp_idx and c_compact < cap:
                    # squeeze dead lanes out mid-node: the remaining probes
                    # (and all later nodes) run at c_compact
                    bound, gid, mult, valid, cap = squeeze(
                        bound, gid, mult, valid, cap, c_compact, i
                    )
                    compacted = True
            if c_compact is not None and not compacted and c_compact < cap:
                # probe-less node (or unreached compact point): after-node
                bound, gid, mult, valid, cap = squeeze(bound, gid, mult, valid, cap, c_compact, i)
        ne = jnp.stack(need_expand) if nsched else jnp.zeros(0, jnp.int32)
        nc = jnp.stack(need_compact) if nsched else jnp.zeros(0, jnp.int32)
        if agg == "count":
            return jnp.sum(jnp.where(valid, mult, 0)), ne, nc
        return bound, valid, mult, ne, nc

    return run


def overflows(cap_plan, need_expand, need_compact):
    """Per-node overflow bits from the executor's reported needs and the
    capacity plan the run used: (ovf_expand, ovf_compact) bool arrays."""
    ne = np.asarray(need_expand)
    nc = np.asarray(need_compact)
    caps = np.asarray(cap_plan.capacities, np.int64)
    cts = np.array(
        [np.iinfo(np.int64).max if c is None else c for c in cap_plan.compact_to], np.int64
    )
    return ne > caps, nc > cts


def make_count_fn(
    plan: FreeJoinPlan,
    capacities: list[int],
    impl: str = "jnp",
    budget: int = 32,
    *,
    schedule: StaticSchedule | None = None,
):
    """Original count-only surface: fn(rel_cols) -> (count, overflowed).
    One scalar overflow flag; no compaction. Kept for benchmarks and dry
    runs — the SPMD driver (core/distributed.py) uses make_executor's need
    vectors directly so its retry loop can grow the offending node."""
    if schedule is None:
        schedule = _static_schedule(plan)
    inner = make_executor(plan, capacities, impl=impl, budget=budget, agg="count", schedule=schedule)
    caps = jnp.asarray(
        tuple(int(c) for c in capacities[: len(schedule)]) or (0,), jnp.int32
    )

    def run(rel_cols):
        count, ne, nc = inner(rel_cols)
        return count, (ne > caps[: ne.shape[0]]).any()

    return run


def _needed_later_static(plan: FreeJoinPlan, k: int, probes, agg: str | None = "count") -> set[str]:
    need: set[str] = set()
    for sa in probes:
        need |= set(sa.vars)
    for node in plan.nodes[k + 1 :]:
        for sa in node:
            need |= set(sa.vars)
    if agg != "count":
        need |= set(plan.query.head)
    return need


def count_query(
    plan: FreeJoinPlan,
    relations,
    capacities: list[int],
    impl: str = "jnp",
    jit: bool = True,
    budget: int = 32,
):
    """Convenience: run the compiled COUNT on host numpy relations."""
    rel_cols = relations_to_cols(plan, relations)
    fn = make_count_fn(plan, capacities, impl, budget)
    if jit:
        fn = jax.jit(fn)
    count, overflow = fn(rel_cols)
    return int(count), bool(overflow)


def relations_to_cols(plan: FreeJoinPlan, relations) -> dict[str, dict[str, jnp.ndarray]]:
    """Device int32 columns for every alias the plan touches."""
    return {
        a: {v: jnp.asarray(relations[a].columns[v], jnp.int32) for v in relations[a].schema}
        for a in {sa.alias for node in plan.nodes for sa in node}
    }


class AdaptiveExecutor:
    """Overflow-retrying driver around make_executor (see module docstring).

    Runs the executor for the current CapacityPlan; if any node reports a
    need above its capacity, jumps exactly that node's capacity (or
    compaction target) to the reported need and re-runs — one retry per
    offending node, not a doubling ladder. Compiled executors are cached per
    capacity vector and the grown plan replaces the initial one, so a stream
    of similar queries pays the retry + recompile once and then runs
    overflow-free.
    """

    def __init__(
        self,
        plan: FreeJoinPlan,
        cap_plan,
        *,
        impl: str = "jnp",
        budget: int = 32,
        agg: str | None = "count",
        jit: bool = True,
        max_retries: int = 12,
    ):
        plan.validate()
        self.plan = plan
        self.cap_plan = cap_plan
        # reuse the schedule the planner already computed, if it rode along
        self.schedule = getattr(cap_plan, "schedule", None) or _static_schedule(plan)
        self.impl = impl
        self.budget = budget
        self.agg = agg
        self.jit = jit
        self.max_retries = max_retries
        self.retries = 0  # total overflow re-runs across calls
        self._cache: dict[tuple, object] = {}

    @property
    def compiles(self) -> int:
        return len(self._cache)

    def _fn(self, cp):
        compact_probe = getattr(cp, "compact_probe", ())
        key = (cp.capacities, cp.compact_to, compact_probe)
        if key not in self._cache:
            fn = make_executor(
                self.plan,
                cp.capacities,
                compact_to=cp.compact_to,
                compact_probe=compact_probe,
                impl=self.impl,
                budget=self.budget,
                agg=self.agg,
                schedule=self.schedule,
            )
            self._cache[key] = jax.jit(fn) if self.jit else fn
        return self._cache[key]

    def __call__(self, rel_cols: dict[str, dict[str, jnp.ndarray]]):
        """agg="count" -> count scalar; agg=None -> (bound, valid, mult)."""
        cp = self.cap_plan
        for _ in range(self.max_retries + 1):
            out = self._fn(cp)(rel_cols)
            ne = np.asarray(out[-2])
            nc = np.asarray(out[-1])
            oe, oc = overflows(cp, ne, nc)
            if not (oe.any() or oc.any()):
                self.cap_plan = cp  # steady state: keep the grown plan
                result = out[:-2]
                return result[0] if self.agg == "count" else result
            for i in np.flatnonzero(oc):
                cp = cp.grow_to(int(i), int(nc[i]), compaction=True)
            for i in np.flatnonzero(oe):
                cp = cp.grow_to(int(i), int(ne[i]))
            self.retries += 1
        raise RuntimeError(
            f"frontier overflow persists after {self.max_retries} retries: {cp}"
        )

    def run_relations(self, relations):
        """Convenience: host relations in, host results out."""
        out = self(relations_to_cols(self.plan, relations))
        if self.agg == "count":
            return int(out)
        return materialize_compiled(*out)


def materialize_compiled(bound, valid, mult):
    """Strip padding lanes from an agg=None result: returns (cols, mult) as
    host numpy arrays over live rows only (the eager engine's contract —
    expand duplicate multiplicities with engine.materialize)."""
    v = np.asarray(valid)
    cols = {name: np.asarray(a)[v].astype(np.int64) for name, a in bound.items()}
    return cols, np.asarray(mult)[v].astype(np.int64)
