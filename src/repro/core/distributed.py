"""Distributed Free Join: HyperCube (Shares) partitioning + SPMD execution.

The paper is single-core; the canonical way to distribute a worst-case
optimal join is the HyperCube / Shares scheme: pick per-variable share
counts p_v with prod(p_v) = P devices, view the device grid as a hypercube
indexed by (h_v(a_v) mod p_v), and send each tuple of R(x_i) to every
device whose coordinates agree on R's variables. Every device then runs the
*same local Free Join* on its fragment; results are a disjoint union
(counts: a psum). One round of communication, no intermediate shuffles —
this composes cleanly with Free Join because the local engine is unchanged.

Two execution paths share the partitioning logic:
  * host path (numpy + eager engine) — used for correctness tests;
  * SPMD path (`shard_map` + compiled engine + psum) — jit-able, lowers on
    the production mesh (see launch/dryrun.py); padded local fragments keep
    shapes static across devices.

The SPMD path is driven by the same planning stack as the local compiled
path (see core/compiled.py's shared-driver contract): spmd_count derives a
CapacityPlan from capacity.plan_capacities over *per-shard* statistics —
fragment sizes are the actual padded per-shard maxima and distinct counts
shrink by the hypercube share of each variable — reusing the query's one
Stats cache and one StaticSchedule. Inside the collective each device runs
make_executor, which reports per-node *required totals*; the psum carries
the count and a pmax carries the needs, and the overflow-retry loop runs on
the host *outside* shard_map: grow exactly the offending node
(CapacityPlan.grow_to), recompile at the new capacity vector, re-run. No
overflow sentinel exists anywhere — spmd_count either returns the exact
(non-negative) count or raises after max_retries.

For acyclic queries hash partitioning on the first join key (shares
concentrated on one variable) recovers the classic distributed hash join as
a special case of the same code path.
"""
from __future__ import annotations

import itertools
from dataclasses import replace

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import api, engine, relcache
from repro.core.capacity import CapacityPlan, plan_capacities
from repro.core.compiled import (
    StaticTrie,
    _static_schedule,
    make_executor,
    overflows,
)
from repro.core.optimizer import Stats
from repro.core.plan import FreeJoinPlan
from repro.relational.npkit import mix64
from repro.relational.relation import Relation
from repro.relational.schema import Query

try:  # top-level alias only exists on newer jax
    shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map


def _query_sig(query: Query) -> tuple:
    """Hashable structural identity of a query (its hyperedges in order)."""
    return tuple((a.alias, a.vars) for a in query.atoms)


# share assignments depend only on (hyperedges, sizes, shard count) — memoized
# process-wide so repeated queries over the same relations skip the search
_shares_cache: dict[tuple, dict[str, int]] = {}
_SHARES_CACHE_MAX = 256


def hypercube_shares(query: Query, sizes: dict[str, int], num_shards: int) -> dict[str, int]:
    """Choose shares p_v (prod = num_shards, powers of two) minimizing the
    max per-device load sum_R |R| / prod_{v in R} p_v. Exhaustive over
    exponent splits — query variable counts are tiny. Memoized on
    (hyperedges, sizes, num_shards): the assignment depends on nothing
    else, so SpmdCounter instances over the same relations share it."""
    key = (_query_sig(query), tuple(sorted(sizes.items())), num_shards)
    hit = _shares_cache.get(key)
    if hit is not None:
        return dict(hit)
    vars_ = list(query.variables)
    logp = int(np.log2(num_shards))
    assert 2**logp == num_shards, "num_shards must be a power of two"
    best, best_load = None, float("inf")

    def loads(assign: dict[str, int]) -> float:
        total = 0.0
        for a in query.atoms:
            frac = 1.0
            for v in a.vars:
                frac /= assign[v]
            total += sizes[a.alias] * frac
        return total

    for combo in itertools.combinations_with_replacement(range(len(vars_)), logp):
        assign = {v: 1 for v in vars_}
        for i in combo:
            assign[vars_[i]] *= 2
        load = loads(assign)
        if load < best_load:
            best, best_load = assign, load
    if best is None:
        # no variables to split over (e.g. a zero-variable query): every
        # shard gets the full input, the all-ones assignment
        best = {v: 1 for v in vars_}
    if len(_shares_cache) >= _SHARES_CACHE_MAX:
        _shares_cache.clear()
    _shares_cache[key] = dict(best)
    return best


def _coords(num_shards: int, shares: dict[str, int], var_order: list[str]):
    """Map shard id -> {var: coordinate} (mixed radix over shared vars)."""
    radices = [(v, shares[v]) for v in var_order if shares[v] > 1]
    out = []
    for s in range(num_shards):
        c, rem = {}, s
        for v, r in radices:
            c[v] = rem % r
            rem //= r
        out.append(c)
    return out


def partition(
    query: Query,
    relations: dict[str, Relation],
    shares: dict[str, int],
    num_shards: int,
) -> list[dict[str, Relation]]:
    """HyperCube partition: each relation row goes to every shard whose
    coordinates match the row's hashed values on the relation's vars."""
    var_order = list(query.variables)
    coords = _coords(num_shards, shares, var_order)
    shards = []
    for c in coords:
        local = {}
        for a in query.atoms:
            rel = relations[a.alias]
            mask = np.ones(rel.num_rows, dtype=bool)
            for v in a.vars:
                if shares[v] > 1:
                    hv = mix64([rel.columns[v].astype(np.int64)]) % shares[v]
                    mask &= hv == c[v]
            local[a.alias] = rel.select(mask)
        shards.append(local)
    return shards


def distributed_join_host(
    query: Query,
    relations: dict[str, Relation],
    num_shards: int,
    plan_tree=None,
    agg: str | None = None,
):
    """Reference distributed execution: partition + per-shard eager Free
    Join + union/sum. Semantically equal to single-node free_join."""
    sizes = {a.alias: relations[a.alias].num_rows for a in query.atoms}
    shares = hypercube_shares(query, sizes, num_shards)
    shards = partition(query, relations, shares, num_shards)
    if agg == "count":
        return sum(api.free_join(query, s, plan_tree, agg="count") for s in shards)
    outs = []
    for s in shards:
        bound, mult = api.free_join(query, s, plan_tree)
        outs.append(engine.materialize(bound, mult, query.head))
    return {
        v: np.concatenate([o[v] for o in outs]) if outs else np.zeros(0, np.int64)
        for v in query.head
    }


# ---------------------------------------------------------------------------
# SPMD path: shard_map(local compiled count) + psum over the mesh.
# ---------------------------------------------------------------------------


def pad_shards_to_dense(shards, query: Query):
    """Stack per-shard fragments into dense (num_shards, N_max) arrays with
    a sentinel-padded tail. Padding rows get key -1 on every column, which
    can never join (real keys are dictionary-encoded >= 0) — they flow
    through the local engine and produce zero matches by construction...
    except an all-pad relation fragment still iterates its sentinels when it
    is a pure cover, so we also hand the local engine a per-shard row count
    and mask the first node (see _mask_first)."""
    out = {}
    counts = {}
    for a in query.atoms:
        nmax = max(max(s[a.alias].num_rows for s in shards), 1)
        cols = {}
        for v in a.vars:
            arr = np.full((len(shards), nmax), -1, dtype=np.int32)
            for i, s in enumerate(shards):
                r = s[a.alias]
                arr[i, : r.num_rows] = r.columns[v].astype(np.int32)
            cols[v] = arr
        out[a.alias] = cols
        counts[a.alias] = np.array([s[a.alias].num_rows for s in shards], np.int32)
    return out, counts


def _mask_pad(cols: dict[str, dict[str, jnp.ndarray]], counts: dict[str, jnp.ndarray]):
    """Replace pad rows' keys with negative sentinels unique across *all*
    relations (a global offset per alias), so pad rows never match any probe
    and never collide with another relation's pad rows."""
    out = {}
    offset = 0
    for alias in sorted(cols):
        c = cols[alias]
        n = next(iter(c.values())).shape[0]
        idx = jnp.arange(n, dtype=jnp.int32)
        pad = idx >= counts[alias]
        out[alias] = {v: jnp.where(pad, -(offset + idx) - 1, a) for v, a in c.items()}
        offset += n
    return out


# hypercube partition + dense padding + device transfer, cached across
# SpmdCounter instances over the very same Relation objects. Relation
# identity is part of the key (id per alias) and every entry is evicted by
# a weakref finalizer the moment any of its relations dies — the dense
# device fragments can neither outlive their relations nor be served to an
# unrelated object that reused a dead relation's address.
_partition_cache = relcache.KeyedCache(max_entries=8)


def _cached_partition(query: Query, relations, shares, num_shards: int):
    """Dense device fragments for (query, shares, num_shards), reused when
    every relation object is identical to the cached entry's."""
    rels = [relations[a.alias] for a in query.atoms]
    key = (
        _query_sig(query),
        tuple(sorted(shares.items())),
        num_shards,
        tuple(id(r) for r in rels),
    )
    hit = _partition_cache.get(key)
    if hit is not None:
        return hit
    shards = partition(query, relations, shares, num_shards)
    dense, counts = pad_shards_to_dense(shards, query)
    dense = jax.tree.map(jnp.asarray, dense)
    counts = jax.tree.map(jnp.asarray, counts)
    _partition_cache.put(key, (dense, counts), rels)
    return dense, counts


# per-shard prebuilt tries: the SPMD build program — one shard_map'd
# build_trie pass per alias, stacked along the shard axis — cached with the
# same identity discipline as the partition. Every later count executor
# (including every grow/recompile retry) takes the built tries as inputs,
# so per-shard builds run once per (relations, shares, schedule, budget)
# per process, not once per call or per retry.
_shard_trie_cache = relcache.KeyedCache(max_entries=8)


def _cached_shard_tries(
    query: Query,
    relations,
    shares,
    num_shards: int,
    dense,
    counts,
    level_ops,
    mesh,
    axis: str,
    impl: str,
    budget: int = 32,
):
    rels = [relations[a.alias] for a in query.atoms]
    key = (
        _query_sig(query),
        tuple(sorted(shares.items())),
        num_shards,
        tuple(sorted((a, lo) for a, lo in level_ops.items())),
        axis,
        impl,
        budget,
        tuple(id(r) for r in rels),
    )
    hit = _shard_trie_cache.get(key)
    if hit is not None:
        return hit
    pspec = jax.sharding.PartitionSpec(axis)
    in_specs = (
        jax.tree.map(lambda _: pspec, dense),
        jax.tree.map(lambda _: pspec, counts),
    )

    def per_shard(cols, cnts):
        cols = jax.tree.map(lambda x: x[0], cols)
        cnts = jax.tree.map(lambda x: x[0], cnts)
        cols = _mask_pad(cols, cnts)
        # lexsort path (key_bits=None): pad sentinels are negative
        tries = {a: StaticTrie(cols[a], level_ops[a], impl, budget) for a in level_ops}
        return jax.tree.map(lambda x: x[None], tries)

    built = jax.jit(
        shard_map(
            per_shard,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=pspec,
            check_rep=False,
        )
    )(dense, counts)
    _shard_trie_cache.put(key, built, rels)
    return built


# grown capacity plans persist across SpmdCounter instances: each process
# pays the overflow retry + recompile once per (plan, relations, shards)
# and every later instance starts overflow-free (planner-derived plans
# only — manual capacities are the caller's to manage); bounded like
# _shares_cache
_cap_plan_cache: dict[tuple, CapacityPlan] = {}
_CAP_PLAN_CACHE_MAX = 256


class _ShardStats:
    """Planner statistics for one hypercube shard, derived from the global
    Stats cache without touching any column again: a fragment of R holds the
    actual padded per-shard row maximum (known after partitioning), and a
    variable sharded p_v ways keeps ~1/p_v of its distinct values."""

    def __init__(self, base: Stats, shares: dict[str, int], sizes: dict[str, int]):
        self.base = base
        self.shares = shares
        self.sizes = sizes

    def size(self, alias: str) -> int:
        return self.sizes[alias]

    def distinct(self, alias: str, var: str) -> float:
        return max(1.0, self.base.distinct(alias, var) / self.shares.get(var, 1))


class SpmdCounter:
    """AdaptiveExecutor's distributed sibling: partition once, then run the
    shard_map'd compiled count with the host-side grow/retry loop outside
    the collective. Compiled executors are cached per capacity vector and
    the grown plan is kept, so repeated calls run overflow-free with no
    recompiles (the steady-state surface the benchmarks measure).

    Three levels persist process-wide across *instances* over the same
    relations: the share assignment (pure function of hyperedges + sizes),
    the dense device fragments (validated by relation object identity), and
    the grown planner-derived CapacityPlan — a new counter for a repeated
    query re-partitions nothing, re-learns nothing, and recompiles only if
    its capacity vector was never seen by this instance."""

    def __init__(
        self,
        query: Query,
        relations: dict[str, Relation],
        plan: FreeJoinPlan,
        capacities: list[int] | None = None,
        mesh: jax.sharding.Mesh = None,
        axis: str = "data",
        impl: str = "jnp",
        *,
        cap_plan: CapacityPlan | None = None,
        safety: float = 2.0,
        max_retries: int = 12,
    ):
        num_shards = mesh.shape[axis]
        sizes = {a.alias: relations[a.alias].num_rows for a in query.atoms}
        self.shares = hypercube_shares(query, sizes, num_shards)
        self._dense, self._counts = _cached_partition(
            query, relations, self.shares, num_shards
        )
        self._plan_key = None  # set only for planner-derived plans
        if cap_plan is not None:
            # reuse the schedule riding on a caller's plan (one walk per
            # query); compaction stays off under shard_map — a reused local
            # plan may carry targets, strip them so overflows() checks what
            # ran
            self.schedule = getattr(cap_plan, "schedule", None) or _static_schedule(plan)
            cap_plan = replace(cap_plan, compact_to=(None,) * len(cap_plan.capacities))
        elif capacities is not None:
            self.schedule = _static_schedule(plan)
            n = len(self.schedule)
            cap_plan = CapacityPlan(
                capacities=tuple(int(c) for c in capacities[:n]),
                compact_to=(None,) * n,
                schedule=self.schedule,
            )
        else:
            self._plan_key = (
                str(plan), _query_sig(query), tuple(sorted(sizes.items())),
                num_shards, safety,
            )
            cached = _cap_plan_cache.get(self._plan_key)
            if cached is not None:
                # a previous instance already learned (grew) this plan; skip
                # the stats pass and start overflow-free
                cap_plan = cached
                self.schedule = cached.schedule
            else:
                # per-shard sizing: padded fragment maxima + share-shrunk
                # distinct counts, same planner as the local path
                self.schedule = _static_schedule(plan)
                frag_sizes = {
                    a: int(next(iter(cols.values())).shape[1])
                    for a, cols in self._dense.items()
                }
                cap_plan = plan_capacities(
                    plan,
                    stats=_ShardStats(Stats(relations), self.shares, frag_sizes),
                    schedule=self.schedule,
                    safety=safety,
                )
                cap_plan = replace(cap_plan, compact_to=(None,) * len(cap_plan.capacities))
        self.plan = plan
        self.cap_plan = cap_plan
        self.mesh = mesh
        self.axis = axis
        self.impl = impl
        self.max_retries = max_retries
        self.retries = 0  # total overflow re-runs across calls
        # build program: per-shard tries, prebuilt once (cached across
        # instances over the same relations) — every count executor and
        # every grow/recompile retry below reuses them as plain inputs
        self._tries = _cached_shard_tries(
            query,
            relations,
            self.shares,
            num_shards,
            self._dense,
            self._counts,
            self.schedule.level_ops,
            mesh,
            axis,
            impl,
        )
        pspec = jax.sharding.PartitionSpec(axis)
        self._in_specs = (jax.tree.map(lambda _: pspec, self._tries),)
        self._cache: dict[tuple, object] = {}

    @property
    def compiles(self) -> int:
        return len(self._cache)

    def _fn(self, cp: CapacityPlan):
        if cp.capacities not in self._cache:
            local = make_executor(
                self.plan, cp.capacities, impl=self.impl, agg="count", schedule=self.schedule
            )
            axis, rspec = self.axis, jax.sharding.PartitionSpec()

            def per_shard(tries):
                tries = jax.tree.map(lambda x: x[0], tries)
                c, ne, nc = local(tries)
                # count by psum; needs by pmax — the host retry loop sizes
                # every device's next capacities to the worst shard's need
                return jax.lax.psum(c, axis), jax.lax.pmax(ne, axis), jax.lax.pmax(nc, axis)

            self._cache[cp.capacities] = jax.jit(
                shard_map(
                    per_shard,
                    mesh=self.mesh,
                    in_specs=self._in_specs,
                    out_specs=(rspec, rspec, rspec),
                    # the probe's early-exit while_loop has no replication
                    # rule; outputs are explicitly psum/pmax-reduced above,
                    # so the check adds nothing here
                    check_rep=False,
                )
            )
        return self._cache[cp.capacities]

    def __call__(self) -> int:
        cp = self.cap_plan
        for _ in range(self.max_retries + 1):
            total, ne, nc = self._fn(cp)(self._tries)
            oe, oc = overflows(cp, ne, nc)
            if not (oe.any() or oc.any()):
                self.cap_plan = cp  # steady state: keep the grown plan
                if self._plan_key is not None:
                    # ...and persist it: the next SpmdCounter over the same
                    # relations starts from the learned capacities
                    if len(_cap_plan_cache) >= _CAP_PLAN_CACHE_MAX:
                        _cap_plan_cache.clear()
                    _cap_plan_cache[self._plan_key] = cp
                total = int(total)
                assert total >= 0, f"spmd count must be non-negative, got {total}"
                return total
            ne, nc = np.asarray(ne), np.asarray(nc)
            # compaction is off under shard_map today, but grow symmetrically
            # with AdaptiveExecutor so the two retry loops cannot diverge
            for i in np.flatnonzero(oc):
                cp = cp.grow_to(int(i), int(nc[i]), compaction=True)
            for i in np.flatnonzero(oe):
                cp = cp.grow_to(int(i), int(ne[i]))
            self.retries += 1
        raise RuntimeError(
            f"spmd frontier overflow persists after {self.max_retries} retries: {cp}"
        )


def spmd_count(
    query: Query,
    relations: dict[str, Relation],
    plan: FreeJoinPlan,
    capacities: list[int] | None = None,
    mesh: jax.sharding.Mesh = None,
    axis: str = "data",
    impl: str = "jnp",
    *,
    cap_plan: CapacityPlan | None = None,
    safety: float = 2.0,
    max_retries: int = 12,
    info: dict | None = None,
) -> int:
    """End-to-end SPMD count: hypercube partition on the host, pad to dense,
    shard over `axis`, run the compiled local engine per device, psum.

    Capacities come from the shared planning stack (see module docstring):
    by default a CapacityPlan over per-shard statistics; `capacities` (a
    manual per-node list) or `cap_plan` override the initial plan. Overflow
    is recovered by SpmdCounter's host-side retry loop — grow the offending
    node to its reported need, recompile, re-run — so the returned count is
    always exact and non-negative; no sentinel exists to leak. `info`, if
    given, receives shares, the final capacity plan, and retry/compile
    counters."""
    counter = SpmdCounter(
        query,
        relations,
        plan,
        capacities,
        mesh,
        axis,
        impl,
        cap_plan=cap_plan,
        safety=safety,
        max_retries=max_retries,
    )
    total = counter()
    if info is not None:
        info.update(
            shares=counter.shares,
            cap_plan=counter.cap_plan,
            retries=counter.retries,
            compiles=counter.compiles,
        )
    return total
