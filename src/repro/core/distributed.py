"""Distributed Free Join: HyperCube (Shares) partitioning + SPMD execution.

The paper is single-core; the canonical way to distribute a worst-case
optimal join is the HyperCube / Shares scheme: pick per-variable share
counts p_v with prod(p_v) = P devices, view the device grid as a hypercube
indexed by (h_v(a_v) mod p_v), and send each tuple of R(x_i) to every
device whose coordinates agree on R's variables. Every device then runs the
*same local Free Join* on its fragment; results are a disjoint union
(counts: a psum). One round of communication, no intermediate shuffles —
this composes cleanly with Free Join because the local engine is unchanged.

Two execution paths share the partitioning logic:
  * host path (numpy + eager engine) — used for correctness tests;
  * SPMD path (`shard_map` + compiled engine + psum) — jit-able, lowers on
    the production mesh (see launch/dryrun.py); padded local fragments keep
    shapes static across devices.

For acyclic queries hash partitioning on the first join key (shares
concentrated on one variable) recovers the classic distributed hash join as
a special case of the same code path.
"""
from __future__ import annotations

import itertools
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import api, engine
from repro.core.compiled import make_count_fn
from repro.core.plan import FreeJoinPlan
from repro.relational.npkit import mix64
from repro.relational.relation import Relation
from repro.relational.schema import Query

try:  # top-level alias only exists on newer jax
    shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map


def hypercube_shares(query: Query, sizes: dict[str, int], num_shards: int) -> dict[str, int]:
    """Choose shares p_v (prod = num_shards, powers of two) minimizing the
    max per-device load sum_R |R| / prod_{v in R} p_v. Exhaustive over
    exponent splits — query variable counts are tiny."""
    vars_ = list(query.variables)
    logp = int(np.log2(num_shards))
    assert 2**logp == num_shards, "num_shards must be a power of two"
    best, best_load = None, float("inf")

    def loads(assign: dict[str, int]) -> float:
        total = 0.0
        for a in query.atoms:
            frac = 1.0
            for v in a.vars:
                frac /= assign[v]
            total += sizes[a.alias] * frac
        return total

    for combo in itertools.combinations_with_replacement(range(len(vars_)), logp):
        assign = {v: 1 for v in vars_}
        for i in combo:
            assign[vars_[i]] *= 2
        load = loads(assign)
        if load < best_load:
            best, best_load = assign, load
    return best


def _coords(num_shards: int, shares: dict[str, int], var_order: list[str]):
    """Map shard id -> {var: coordinate} (mixed radix over shared vars)."""
    radices = [(v, shares[v]) for v in var_order if shares[v] > 1]
    out = []
    for s in range(num_shards):
        c, rem = {}, s
        for v, r in radices:
            c[v] = rem % r
            rem //= r
        out.append(c)
    return out


def partition(
    query: Query,
    relations: dict[str, Relation],
    shares: dict[str, int],
    num_shards: int,
) -> list[dict[str, Relation]]:
    """HyperCube partition: each relation row goes to every shard whose
    coordinates match the row's hashed values on the relation's vars."""
    var_order = list(query.variables)
    coords = _coords(num_shards, shares, var_order)
    shards = []
    for c in coords:
        local = {}
        for a in query.atoms:
            rel = relations[a.alias]
            mask = np.ones(rel.num_rows, dtype=bool)
            for v in a.vars:
                if shares[v] > 1:
                    hv = mix64([rel.columns[v].astype(np.int64)]) % shares[v]
                    mask &= hv == c[v]
            local[a.alias] = rel.select(mask)
        shards.append(local)
    return shards


def distributed_join_host(
    query: Query,
    relations: dict[str, Relation],
    num_shards: int,
    plan_tree=None,
    agg: str | None = None,
):
    """Reference distributed execution: partition + per-shard eager Free
    Join + union/sum. Semantically equal to single-node free_join."""
    sizes = {a.alias: relations[a.alias].num_rows for a in query.atoms}
    shares = hypercube_shares(query, sizes, num_shards)
    shards = partition(query, relations, shares, num_shards)
    if agg == "count":
        return sum(api.free_join(query, s, plan_tree, agg="count") for s in shards)
    outs = []
    for s in shards:
        bound, mult = api.free_join(query, s, plan_tree)
        outs.append(engine.materialize(bound, mult, query.head))
    return {
        v: np.concatenate([o[v] for o in outs]) if outs else np.zeros(0, np.int64)
        for v in query.head
    }


# ---------------------------------------------------------------------------
# SPMD path: shard_map(local compiled count) + psum over the mesh.
# ---------------------------------------------------------------------------


def pad_shards_to_dense(shards, query: Query):
    """Stack per-shard fragments into dense (num_shards, N_max) arrays with
    a sentinel-padded tail. Padding rows get key -1 on every column, which
    can never join (real keys are dictionary-encoded >= 0) — they flow
    through the local engine and produce zero matches by construction...
    except an all-pad relation fragment still iterates its sentinels when it
    is a pure cover, so we also hand the local engine a per-shard row count
    and mask the first node (see _mask_first)."""
    out = {}
    counts = {}
    for a in query.atoms:
        nmax = max(max(s[a.alias].num_rows for s in shards), 1)
        cols = {}
        for v in a.vars:
            arr = np.full((len(shards), nmax), -1, dtype=np.int32)
            for i, s in enumerate(shards):
                r = s[a.alias]
                arr[i, : r.num_rows] = r.columns[v].astype(np.int32)
            cols[v] = arr
        out[a.alias] = cols
        counts[a.alias] = np.array([s[a.alias].num_rows for s in shards], np.int32)
    return out, counts


def _mask_pad(cols: dict[str, dict[str, jnp.ndarray]], counts: dict[str, jnp.ndarray]):
    """Replace pad rows' keys with negative sentinels unique across *all*
    relations (a global offset per alias), so pad rows never match any probe
    and never collide with another relation's pad rows."""
    out = {}
    offset = 0
    for alias in sorted(cols):
        c = cols[alias]
        n = next(iter(c.values())).shape[0]
        idx = jnp.arange(n, dtype=jnp.int32)
        pad = idx >= counts[alias]
        out[alias] = {v: jnp.where(pad, -(offset + idx) - 1, a) for v, a in c.items()}
        offset += n
    return out


def spmd_count(
    query: Query,
    relations: dict[str, Relation],
    plan: FreeJoinPlan,
    capacities: list[int],
    mesh: jax.sharding.Mesh,
    axis: str = "data",
    impl: str = "jnp",
):
    """End-to-end SPMD count: hypercube partition on the host, pad to dense,
    shard over `axis`, run the compiled local engine per device, psum."""
    num_shards = mesh.shape[axis]
    sizes = {a.alias: relations[a.alias].num_rows for a in query.atoms}
    shares = hypercube_shares(query, sizes, num_shards)
    shards = partition(query, relations, shares, num_shards)
    dense, counts = pad_shards_to_dense(shards, query)
    local = make_count_fn(plan, capacities, impl=impl)

    def per_shard(cols, cnts):
        cols = jax.tree.map(lambda x: x[0], cols)
        cnts = jax.tree.map(lambda x: x[0], cnts)
        cols = _mask_pad(cols, cnts)
        c, ovf = local(cols)
        c = jnp.where(ovf, -(2**30), c)
        return jax.lax.psum(c, axis)

    pspec = jax.sharding.PartitionSpec(axis)
    dense_j = jax.tree.map(jnp.asarray, dense)
    counts_j = jax.tree.map(jnp.asarray, counts)
    fn = jax.jit(
        shard_map(
            per_shard,
            mesh=mesh,
            in_specs=(
                jax.tree.map(lambda _: pspec, dense_j),
                jax.tree.map(lambda _: pspec, counts_j),
            ),
            out_specs=jax.sharding.PartitionSpec(),
        )
    )
    total = fn(dense_j, counts_j)
    return int(total)
