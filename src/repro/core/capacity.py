"""Capacity planning for the compiled (static-shape) Free Join path.

The compiled executor (core/compiled.py) runs every plan node into a
fixed-capacity frontier buffer; picking those capacities used to be the
caller's problem. This module derives them from the optimizer's per-prefix
cardinality estimates (optimizer.estimate_prefixes), capped by the AGM
bound of the prefix sub-query — the estimates give the expected frontier,
the AGM bound gives a sound worst case, and a safety factor in between
absorbs estimation error. Capacities are rounded up to the kernel block
size so the Pallas grids stay aligned.

The planner also schedules *frontier compaction*: when a node's probes are
estimated to kill enough lanes that the live fraction drops below a
threshold, the plan records a compacted (smaller) capacity for the frontier
going into the next node; the runner squeezes the valid lanes densely into
that buffer (kernels/compact.py), so all later nodes pay for live rows
rather than for the largest buffer ever allocated.

Under-estimates are recoverable: the executor reports every node's
*required* total and the adaptive runner jumps exactly the offending
capacity to that need and retries (see compiled.AdaptiveExecutor and
distributed.spmd_count — the same plan drives both the local and the SPMD
path), so the plan here only has to be right on average, not in the worst
case.

Mutating relations (core/relcache.py) need no special casing here: the
Stats the estimates are built from are delta-aware — Stats.size reports
live rows (tombstones excluded) and distinct counts are maintained
incrementally on append — so capacity plans over a mutated relation see
its current live cardinalities, not the physical padded buffers.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace

from repro.core.optimizer import NodeEstimate, StageStats, Stats, estimate_prefixes, stage_est
from repro.core.plan import FreeJoinPlan
from repro.kernels.csr_expand import OBLK
from repro.relational.relation import Relation

try:  # scipy ships in the container; keep a sound fallback if absent
    from scipy.optimize import linprog as _linprog
except Exception:  # pragma: no cover
    _linprog = None


# AGM bounds are pure functions of (hyperedges, sizes) and each linprog call
# costs host milliseconds; planning calls agm_bound once per node *and* once
# per probe prefix, so a repeated query re-derives identical bounds every
# call. Memoized process-wide (bounded), the per-call planning pass costs
# dict lookups — part of dropping build/planning cost out of warm calls.
_agm_cache: dict[tuple, float] = {}
_AGM_CACHE_MAX = 4096


def agm_bound(edges: dict[str, tuple[str, ...]], sizes: dict[str, float]) -> float:
    """AGM bound of a join: min over fractional edge covers x of
    prod_R |R|^x_R, via the LP  min sum x_R log|R|  s.t. every variable is
    covered. Falls back to a greedy integral cover (still a valid upper
    bound, just looser) when scipy is unavailable. Memoized on the exact
    (edges, sizes) contents."""
    aliases = [a for a, vs in edges.items() if vs]
    variables = sorted({v for a in aliases for v in edges[a]})
    if not aliases or not variables:
        return 1.0
    memo_key = (
        tuple(sorted((a, tuple(edges[a])) for a in aliases)),
        tuple(sorted((a, float(sizes[a])) for a in aliases)),
    )
    hit = _agm_cache.get(memo_key)
    if hit is not None:
        return hit
    logs = [math.log(max(1.0, sizes[a])) for a in aliases]
    bound = None
    if _linprog is not None:
        a_ub = [[-1.0 if v in edges[a] else 0.0 for a in aliases] for v in variables]
        res = _linprog(logs, A_ub=a_ub, b_ub=[-1.0] * len(variables), bounds=(0, 1), method="highs")
        if res.status == 0:
            bound = float(math.exp(res.fun))
    if bound is None:
        cover = 0.0
        for v in variables:  # greedy integral cover: cheapest edge per variable
            cover += min(lg for a, lg in zip(aliases, logs) if v in edges[a])
        bound = float(math.exp(min(cover, sum(logs))))
    if len(_agm_cache) >= _AGM_CACHE_MAX:
        _agm_cache.clear()
    _agm_cache[memo_key] = bound
    return bound


def _round_block(x: float, block: int) -> int:
    return max(block, int(math.ceil(x / block)) * block)


def node_agm_bounds(schedule, sizes: dict[str, float]) -> list[float]:
    """AGM bound of each executed node's prefix sub-query, in schedule
    order: the bound is taken right after the node's cover level is
    consumed (exactly where plan_capacities caps the expansion buffer),
    then the node's probes extend the prefix for the next node. Shared by
    the capacity planner's sizing walk and the static verifier
    (repro.analysis.planlint), so "capacity exceeds the AGM cap" means the
    same thing in both places."""
    prefix: dict[str, tuple[str, ...]] = {a: () for a in sizes}
    out: list[float] = []
    for _k, cover, probes in schedule:
        prefix[cover.alias] = prefix[cover.alias] + tuple(cover.vars)
        out.append(agm_bound(prefix, sizes))
        for sa in probes:
            prefix[sa.alias] = prefix[sa.alias] + tuple(sa.vars)
    return out


class CapacityQuotaError(RuntimeError):
    """A query's frontier requirement exceeded its admission quota.

    Raised by the adaptive runner *instead of* growing a buffer past
    `max_capacity`: under multi-tenant serving, growing (and therefore
    recompiling) the shared batched executor for one pathological query
    would stall every co-batched tenant, so the runner surfaces the
    violation and lets the serving layer reject exactly the offending
    request. `lane` identifies the batch lane whose reported need drove the
    violation (None for unbatched runs)."""

    def __init__(self, stage: int, node: int, need: int, cap: int, lane: int | None = None):
        self.stage = stage
        self.node = node
        self.need = need
        self.cap = cap
        self.lane = lane
        who = f" (batch lane {lane})" if lane is not None else ""
        super().__init__(
            f"stage {stage} node {node} needs {need} frontier lanes, "
            f"over the {cap}-lane capacity quota{who}"
        )


@dataclass(frozen=True)
class CapacityPlan:
    """Static per-node frontier sizing for one compiled plan.

    capacities[i] is the expansion buffer for the i-th executed node;
    compact_to[i] (or None) is the capacity the frontier is squeezed into
    at that node's compact point. compact_probe[i] says where that point
    is: the number of probes run before compacting — mid-node when an early
    probe is predicted to kill most lanes (the remaining probes then run at
    the compacted width, budget x fewer gather rounds each), len(probes)
    for after the whole node. estimates/agm record where the numbers came
    from (estimates per node, AGM bound of the node's prefix sub-query)."""

    capacities: tuple[int, ...]
    compact_to: tuple[int | None, ...]
    compact_probe: tuple[int, ...] = ()
    estimates: tuple[NodeEstimate, ...] = ()
    agm: tuple[float, ...] = ()
    block: int = OBLK
    # the query's StaticSchedule, computed once by the planner and reused by
    # every executor build (AdaptiveExecutor, spmd_count)
    schedule: object = field(default=None, compare=False, repr=False)

    def grow(self, node: int, *, compaction: bool = False) -> "CapacityPlan":
        """Double one node's capacity (the adaptive runner's overflow
        response). Growing a compaction target past its node capacity
        disables that compaction instead."""
        if compaction:
            cur = self.compact_to[node]
            new = None if cur is None or 2 * cur >= self.capacities[node] else 2 * cur
            ct = tuple(new if i == node else c for i, c in enumerate(self.compact_to))
            return replace(self, compact_to=ct)
        caps = tuple(2 * c if i == node else c for i, c in enumerate(self.capacities))
        # a bigger buffer lowers the live fraction; keep compaction targets
        ct = tuple(
            None if i == node and c is not None and c >= caps[node] else c
            for i, c in enumerate(self.compact_to)
        )
        return replace(self, capacities=caps, compact_to=ct)

    def grow_to(self, node: int, need: int, *, compaction: bool = False) -> "CapacityPlan":
        """Jump one node's capacity straight to a reported requirement (the
        executor returns exact per-node totals), block-rounded. At least
        doubles, so needs under-measured behind an upstream overflow still
        make geometric progress. A compaction target grown past its node
        capacity is disabled instead."""
        need = int(need)
        if compaction:
            cur = self.compact_to[node]
            if cur is None:
                return self
            new = max(2 * cur, _round_block(need, self.block))
            ct = tuple(
                (None if new >= self.capacities[node] else new) if i == node else c
                for i, c in enumerate(self.compact_to)
            )
            return replace(self, compact_to=ct)
        new = max(2 * self.capacities[node], _round_block(need, self.block))
        caps = tuple(new if i == node else c for i, c in enumerate(self.capacities))
        ct = tuple(
            None if i == node and c is not None and c >= caps[node] else c
            for i, c in enumerate(self.compact_to)
        )
        return replace(self, capacities=caps, compact_to=ct)

    def shrink_to(self, node: int, need: int, *, compaction: bool = False) -> "CapacityPlan":
        """Tighten one node's capacity (or compaction target) down to a
        *measured* requirement, block-rounded — the adaptive runner's
        response to a buffer that ran mostly empty. Callers only shrink
        when the buffer exceeds twice the rounded need, so a later small
        overflow's grow_to (which at least doubles) lands back inside the
        hysteresis band instead of oscillating."""
        new = _round_block(max(1, int(need)), self.block)
        if compaction:
            cur = self.compact_to[node]
            if cur is None or new >= cur:
                return self
            ct = tuple(new if i == node else c for i, c in enumerate(self.compact_to))
            return replace(self, compact_to=ct)
        if new >= self.capacities[node]:
            return self
        caps = tuple(new if i == node else c for i, c in enumerate(self.capacities))
        # a compaction target at or above the shrunk capacity is pointless
        ct = tuple(
            None if i == node and c is not None and c >= caps[node] else c
            for i, c in enumerate(self.compact_to)
        )
        return replace(self, capacities=caps, compact_to=ct)

    def cells(self) -> int:
        """Total planned frontier cells — the admission-control currency:
        quotas compare this against a per-query budget before any compile."""
        return int(sum(self.capacities))

    def __str__(self):
        parts = []
        for i, (cap, ct) in enumerate(zip(self.capacities, self.compact_to)):
            at = f"@p{self.compact_probe[i]}" if ct is not None and self.compact_probe else ""
            parts.append(f"n{i}:{cap}" + (f"->{ct}{at}" if ct is not None else ""))
        return "CapacityPlan[" + ", ".join(parts) + "]"


@dataclass(frozen=True)
class ChainCapacityPlan:
    """Capacity plans for a whole bushy plan run as one compiled chain:
    one CapacityPlan per stage, root last (`names` aligned). The adaptive
    runner grows exactly the offending (stage, node) pair; growing any
    stage recompiles the chain, because a stage's output buffer width is a
    static shape of every downstream trie build."""

    names: tuple[str, ...]
    stages: tuple["CapacityPlan", ...]

    def key(self) -> tuple:
        """Hashable identity of every static shape in the chain (the
        executor-cache key)."""
        return tuple(
            (cp.capacities, cp.compact_to, cp.compact_probe) for cp in self.stages
        )

    def grow_to(self, stage: int, node: int, need: int, *, compaction: bool = False):
        cp = self.stages[stage].grow_to(node, need, compaction=compaction)
        if cp is self.stages[stage]:
            return self
        return replace(
            self, stages=tuple(cp if i == stage else c for i, c in enumerate(self.stages))
        )

    def shrink_to(self, stage: int, node: int, need: int, *, compaction: bool = False):
        cp = self.stages[stage].shrink_to(node, need, compaction=compaction)
        if cp is self.stages[stage]:
            return self
        return replace(
            self, stages=tuple(cp if i == stage else c for i, c in enumerate(self.stages))
        )

    def cells(self) -> int:
        """Total planned frontier cells across every stage (see
        CapacityPlan.cells)."""
        return sum(cp.cells() for cp in self.stages)

    def with_schedules(self, schedules) -> "ChainCapacityPlan":
        return replace(
            self,
            stages=tuple(replace(cp, schedule=s) for cp, s in zip(self.stages, schedules)),
        )

    def __str__(self):
        return "Chain[" + "; ".join(
            f"{n}:{cp}" for n, cp in zip(self.names, self.stages)
        ) + "]"


def plan_capacities(
    plan: FreeJoinPlan,
    relations: dict[str, Relation] | None = None,
    *,
    stats: Stats | None = None,
    schedule=None,
    safety: float = 2.0,
    block: int = OBLK,
    compact_threshold: float = 0.25,
    max_capacity: int = 1 << 22,
    compact_output: bool = False,
    feedback=None,
) -> CapacityPlan:
    """Derive a CapacityPlan for `plan` (see module doc).

    Statistics come from `stats` — any object with .size(alias) and
    .distinct(alias, var) — or are computed from `relations`. The
    distributed driver passes per-shard stats (sizes and distinct counts
    shrunk by the hypercube shares); the local driver passes its query-wide
    Stats cache. `schedule` is the query's StaticSchedule if already
    computed; it is stored on the returned plan for executor builds.

    safety: multiplier on the cardinality estimates; compact_threshold:
    schedule compaction after a node when est-after / capacity falls below
    this; max_capacity: clamp on planned (not grown) capacities.
    compact_output: allow a compact point on the final node too — for
    non-root stages of a chained bushy plan, whose output buffer feeds the
    next stage's trie build (a squeezed buffer means a smaller lexsort),
    there is always "more work" after the last probe.
    feedback: a relcache.CardFeedback — prefix estimates are replaced by
    measured cardinalities from prior runs where recorded (see
    optimizer.prefix_card), so a warm query's buffers are sized from
    measurements instead of independence assumptions."""
    from repro.core.compiled import _static_schedule  # deferred: avoids a cycle

    if stats is None:
        stats = Stats(relations)
    if schedule is None:
        schedule = _static_schedule(plan)
    estimates = estimate_prefixes(plan, stats=stats, schedule=schedule, feedback=feedback)
    sizes = {
        a: float(max(1, stats.size(a)))
        for a in {sa.alias for node in plan.nodes for sa in node}
    }
    prefix: dict[str, tuple[str, ...]] = {a: () for a in sizes}
    caps: list[int] = []
    compact: list[int | None] = []
    compact_probe: list[int] = []
    agms: list[float] = []
    for (_k, cover, probes), est in zip(schedule.entries, estimates):
        prefix[cover.alias] = prefix[cover.alias] + tuple(cover.vars)
        bound = agm_bound(prefix, sizes)
        cap = _round_block(min(max(1.0, est.expand) * safety, bound, float(max_capacity)), block)
        last = est is estimates[-1] and not compact_output
        # earliest probe after which the predicted live fraction collapses:
        # compacting right there lets every remaining probe (and all later
        # nodes) run at the squeezed width
        target: int | None = None
        cp_idx = len(probes)
        for j, sa in enumerate(probes):
            prefix[sa.alias] = prefix[sa.alias] + tuple(sa.vars)
            more_work = (j + 1 < len(probes)) or not last
            if target is not None or not more_work:
                continue
            a_est = est.probe_after[j]
            t = _round_block(min(max(1.0, a_est) * safety, agm_bound(prefix, sizes)), block)
            if a_est < compact_threshold * cap and t < cap:
                target, cp_idx = t, j + 1
        if compact_output and est is estimates[-1] and target is None:
            # a stage's final frontier is the next stage's trie, whose build
            # cost scales with the static buffer width — squeeze it whenever
            # the estimate says the buffer is oversized, selective or not.
            # No safety factor here: a too-small target is recovered by one
            # compact-overflow retry that jumps to the *measured* live count,
            # so steady state converges to a tight output buffer.
            t = _round_block(min(max(1.0, est.after), agm_bound(prefix, sizes)), block)
            if t < cap:
                target, cp_idx = t, len(probes)
        caps.append(cap)
        compact.append(target)
        compact_probe.append(cp_idx)
        agms.append(bound)
    return CapacityPlan(
        capacities=tuple(caps),
        compact_to=tuple(compact),
        compact_probe=tuple(compact_probe),
        estimates=tuple(estimates),
        agm=tuple(agms),
        block=block,
        schedule=schedule,
    )


def plan_chain_capacities(
    stages,
    *,
    stats: Stats,
    safety: float = 2.0,
    block: int = OBLK,
    compact_threshold: float = 0.25,
    max_capacity: int = 1 << 22,
    feedback=None,
) -> ChainCapacityPlan:
    """Capacity-plan a whole stage chain in one pass (no materialization).

    stages: ((name, FreeJoinPlan), ...) root last, each plan's query built
    over the stage's atoms (which may reference earlier stage names).
    `stats` covers the *base* relations only; stage outputs are answered by
    a StageStats view from the optimizer's cardinality estimates — each
    stage's estimated Est (size + per-var distincts) registers before the
    next stage plans, so stage output estimates feed every downstream
    prefix estimate and AGM bound. Non-root stages plan with
    compact_output=True so their output buffers (the next trie's static
    width) get squeezed when the estimates say most lanes are dead."""
    sstats = StageStats(stats)
    cps = []
    for i, (name, plan) in enumerate(stages):
        root = i == len(stages) - 1
        cps.append(
            plan_capacities(
                plan,
                stats=sstats,
                safety=safety,
                block=block,
                compact_threshold=compact_threshold,
                max_capacity=max_capacity,
                compact_output=not root,
                feedback=feedback,
            )
        )
        if not root:
            sstats.register(name, stage_est(plan.query.atoms, sstats))
    return ChainCapacityPlan(names=tuple(n for n, _ in stages), stages=tuple(cps))
