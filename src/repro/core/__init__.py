# Free Join (Wang, Willsey, Suciu — SIGMOD 2023): the paper's primary
# contribution. Plans (binary2fj + factor), COLT tries, the vectorized
# Free Join engine, baselines, optimizer, the capacity-planned compiled
# path, and the distributed engine.
from repro.core import faults, membudget
from repro.core.api import (
    ExecOptions,
    binary_join,
    compiled_free_join,
    free_join,
    generic_join,
    to_sorted_tuples,
)
from repro.core.capacity import (
    CapacityPlan,
    CapacityQuotaError,
    ChainCapacityPlan,
    agm_bound,
    plan_capacities,
    plan_chain_capacities,
)
from repro.core.colt import Colt
from repro.core.compiled import AdaptiveExecutor, StaticSchedule, make_chain_executor
from repro.core.engine import ExecStats, execute, materialize
from repro.core.optimizer import (
    Est,
    JoinOrderOptimizer,
    Stats,
    device_cost,
    estimate_prefixes,
    optimize,
)
from repro.core.relcache import FEEDBACK, CardFeedback
from repro.core.plan import (
    BinaryPlan,
    FreeJoinPlan,
    Subatom,
    binary2fj,
    factor,
    gj_plan,
    linear,
    var_order_from_fj,
)

__all__ = [
    "AdaptiveExecutor",
    "faults",
    "membudget",
    "CapacityPlan",
    "CapacityQuotaError",
    "ChainCapacityPlan",
    "ExecOptions",
    "Est",
    "FEEDBACK",
    "CardFeedback",
    "JoinOrderOptimizer",
    "device_cost",
    "Stats",
    "StaticSchedule",
    "agm_bound",
    "binary_join",
    "compiled_free_join",
    "estimate_prefixes",
    "free_join",
    "make_chain_executor",
    "plan_capacities",
    "plan_chain_capacities",
    "generic_join",
    "to_sorted_tuples",
    "Colt",
    "ExecStats",
    "execute",
    "materialize",
    "optimize",
    "BinaryPlan",
    "FreeJoinPlan",
    "Subatom",
    "binary2fj",
    "factor",
    "gj_plan",
    "linear",
    "var_order_from_fj",
]
