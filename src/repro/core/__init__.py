# Free Join (Wang, Willsey, Suciu — SIGMOD 2023): the paper's primary
# contribution. Plans (binary2fj + factor), COLT tries, the vectorized
# Free Join engine, baselines, optimizer, and the distributed engine.
from repro.core.api import binary_join, free_join, generic_join, to_sorted_tuples
from repro.core.colt import Colt
from repro.core.engine import ExecStats, execute, materialize
from repro.core.optimizer import optimize
from repro.core.plan import (
    BinaryPlan,
    FreeJoinPlan,
    Subatom,
    binary2fj,
    factor,
    gj_plan,
    linear,
    var_order_from_fj,
)

__all__ = [
    "binary_join",
    "free_join",
    "generic_join",
    "to_sorted_tuples",
    "Colt",
    "ExecStats",
    "execute",
    "materialize",
    "optimize",
    "BinaryPlan",
    "FreeJoinPlan",
    "Subatom",
    "binary2fj",
    "factor",
    "gj_plan",
    "linear",
    "var_order_from_fj",
]
