"""The Free Join algorithm (Fig. 7), executed fully vectorized.

The paper batches the cover iteration and probes per relation (Sec 4.3,
Fig. 13); on vector hardware we take that to its limit: the *entire frontier*
(the set of partially-bound tuples at the current plan node) is one batch.
Each plan node is executed as: expand the frontier along the cover's trie
level, then probe every other subatom's trie level with whole-column keys,
filtering the frontier by the hit mask. Per-tuple recursion disappears; the
recursion depth of Fig. 7 becomes a sequential walk over plan nodes.

Bag semantics: duplicate tuples live below the deepest trie level; instead of
expanding them eagerly we carry a `mult` column and expand once at output
(duplicates agree on all bound vars, so this is exact).

Factorized counting (Sec 4.4 "factorized representation... to compress large
outputs"): with agg="count", a cover at its last, unforced level whose vars
are never used again contributes only its subtree sizes to `mult` — no
expansion. This is the optimization behind the paper's Fig. 19.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.colt import Colt
from repro.core.plan import FreeJoinPlan, Subatom
from repro.relational.relation import Relation


@dataclass
class Frontier:
    n: int
    mult: np.ndarray
    bound: dict[str, np.ndarray] = field(default_factory=dict)
    gid: dict[str, np.ndarray] = field(default_factory=dict)

    def expand(self, fr: np.ndarray) -> None:
        self.mult = self.mult[fr]
        self.bound = {k: v[fr] for k, v in self.bound.items()}
        self.gid = {k: v[fr] for k, v in self.gid.items()}
        self.n = len(fr)

    def filter(self, mask: np.ndarray) -> None:
        self.mult = self.mult[mask]
        self.bound = {k: v[mask] for k, v in self.bound.items()}
        self.gid = {k: v[mask] for k, v in self.gid.items()}
        self.n = int(mask.sum()) if mask.dtype == bool else len(mask)

    def gids_for(self, alias: str) -> np.ndarray:
        if alias not in self.gid:
            self.gid[alias] = np.zeros(self.n, dtype=np.int64)
        return self.gid[alias]


@dataclass
class ExecStats:
    build_ns: int = 0
    max_frontier: int = 0
    probes: int = 0
    expansions: int = 0


def execute(
    plan: FreeJoinPlan,
    relations: dict[str, Relation],
    *,
    mode: str | dict[str, str] = "colt",
    dynamic_cover: bool = True,
    agg: str | None = None,
    stats: ExecStats | None = None,
    tries: dict[str, Colt] | None = None,
):
    """Run a Free Join plan. Returns (bound, mult) where bound maps each
    query variable to a column and mult is the per-row multiplicity — or the
    scalar count when agg == "count".

    `tries` lets a caller reuse already-(partially-)built Colt tries across
    calls of the same plan shape; stats.build_ns then accounts only the
    forcing done by this call (before/after snapshot, not the tries'
    lifetime totals)."""
    plan.validate()
    parts = plan.partitions()
    modes = mode if isinstance(mode, dict) else {a: mode for a in parts}
    if tries is None:
        # construction may force levels (simple/slt modes): that build time
        # belongs to this call, so the snapshot baseline is zero
        build_ns_before = 0
        tries = {
            alias: Colt(relations[alias], parts[alias], mode=modes.get(alias, "colt"))
            for alias in parts
        }
    else:
        build_ns_before = sum(t.build_ns for t in tries.values())
    depth = {alias: 0 for alias in parts}
    f = Frontier(n=1, mult=np.ones(1, dtype=np.int64))

    for k, node in enumerate(plan.nodes):
        subs = [sa for sa in node if sa.vars]
        if not subs:
            continue
        cover = _choose_cover(plan, k, subs, tries, depth, dynamic_cover, f)
        probes = [sa for sa in subs if sa is not cover]

        needed_later = _needed_later(plan, k, probes, agg)
        if (
            agg == "count"
            and not (set(cover.vars) & needed_later)
            and not any(v in f.bound for v in cover.vars)
            and depth[cover.alias] == tries[cover.alias].L - 1
            and depth[cover.alias] == tries[cover.alias].forced_depth
        ):
            # factorized count: fold subtree sizes into mult, skip expansion
            t = tries[cover.alias]
            g = f.gids_for(cover.alias)
            f.mult = f.mult * t.subtree_sizes(depth[cover.alias], g)
            f.gid.pop(cover.alias, None)
            depth[cover.alias] = t.L
        else:
            _iterate_cover(f, cover, tries, depth, stats)
        for sa in probes:
            _probe(f, sa, tries, depth, stats)
            if f.n == 0:
                break
        if stats is not None:
            stats.max_frontier = max(stats.max_frontier, f.n)
        if f.n == 0:
            break

    if stats is not None:
        stats.build_ns += sum(t.build_ns for t in tries.values()) - build_ns_before
    if agg == "count":
        return int(f.mult.sum())
    return f.bound, f.mult


def _choose_cover(plan, k, subs, tries, depth, dynamic, f: "Frontier"):
    covers = [sa for sa in plan.covers(k) if sa.vars]
    covers = [sa for sa in covers if any(sa is s for s in subs)]
    if not covers:
        raise ValueError(f"node {k} has no usable cover")
    if not dynamic or len(covers) == 1:
        return covers[0]
    # Sec 4.4, frontier-conditional: iterate the cover whose expansion is
    # smallest *given the current frontier* (exact per-subtrie sums; the
    # paper's fewest-keys rule is the tuple-at-a-time approximation).
    return min(
        covers,
        key=lambda sa: tries[sa.alias].iter_cost(depth[sa.alias], f.gids_for(sa.alias)),
    )


def _needed_later(plan, k, probes, agg) -> set[str]:
    need: set[str] = set()
    for sa in probes:
        need |= set(sa.vars)
    for node in plan.nodes[k + 1 :]:
        for sa in node:
            need |= set(sa.vars)
    if agg != "count":
        need |= set(plan.query.head)
    return need


def _iterate_cover(f: Frontier, sa: Subatom, tries, depth, stats) -> None:
    t: Colt = tries[sa.alias]
    d = depth[sa.alias]
    gids = f.gids_for(sa.alias)
    fr, cols, new_gids = t.iter_expand(d, gids)
    # A cover may contain vars bound by earlier nodes (possible after
    # dynamic cover selection): those act as a semijoin filter, not a
    # rebinding.
    rebound = [i for i, v in enumerate(sa.vars) if v in f.bound]
    f.expand(fr)
    if rebound:
        keep = np.ones(len(fr), dtype=bool)
        for i in rebound:
            keep &= cols[i] == f.bound[sa.vars[i]]
        f.filter(keep)
        cols = [c[keep] for c in cols]
        if new_gids is not None:
            new_gids = new_gids[keep]
    for v, c in zip(sa.vars, cols):
        if v not in f.bound:
            f.bound[v] = c
    if stats is not None:
        stats.expansions += len(fr)
    depth[sa.alias] = d + 1
    if new_gids is None:
        f.gid.pop(sa.alias, None)  # exhausted by direct row iteration
        return
    if depth[sa.alias] == t.L:
        f.mult = f.mult * t.leaf_counts(new_gids)
        f.gid.pop(sa.alias, None)
    else:
        f.gid[sa.alias] = new_gids


def _probe(f: Frontier, sa: Subatom, tries, depth, stats) -> None:
    t: Colt = tries[sa.alias]
    d = depth[sa.alias]
    gids = f.gids_for(sa.alias)
    keys = [f.bound[v] for v in sa.vars]
    res = t.probe(d, gids, keys)
    if stats is not None:
        stats.probes += len(res)
    hit = res >= 0
    res = res[hit]
    f.filter(hit)
    depth[sa.alias] = d + 1
    if depth[sa.alias] == t.L:
        f.mult = f.mult * t.leaf_counts(res)
        f.gid.pop(sa.alias, None)
    else:
        f.gid[sa.alias] = res


def materialize(bound: dict[str, np.ndarray], mult: np.ndarray, head) -> dict[str, np.ndarray]:
    """Expand multiplicities into physical duplicate rows (bag output)."""
    if len(mult) == 0:
        # empty result: later nodes may never have bound their vars
        return {v: bound.get(v, np.zeros(0, dtype=np.int64)) for v in head}
    if mult.max(initial=1) > 1:
        idx = np.repeat(np.arange(len(mult)), mult)
        return {v: bound[v][idx] for v in head}
    return {v: bound[v] for v in head}
