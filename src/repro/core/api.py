"""Top-level drivers: Free Join, Generic Join, and binary hash join.

Each driver takes a query, relations, and a binary plan (tree). Bushy plans
are decomposed into left-deep stages (Sec 2.2); every non-root stage is
materialized into a fresh relation before its parent runs — the paper's
(intentionally simple) materialization strategy.

`free_join(compiled=True)` (or `compiled_free_join`) routes the root stage
through the static-shape executor instead: query -> cost-based binary plan
-> binary2fj -> factor -> capacity.plan_capacities -> compiled.
AdaptiveExecutor. No manual capacities — buffer sizes come from the
optimizer's estimates capped by the AGM bound, and overflow is recovered by
per-node geometric growth.
"""
from __future__ import annotations

import numpy as np

from repro.core import engine
from repro.core.plan import (
    BinaryPlan,
    FreeJoinPlan,
    binary2fj,
    factor,
    gj_plan,
    var_order_from_fj,
)
from repro.core.optimizer import Stats, optimize
from repro.relational.relation import Relation
from repro.relational.schema import Atom, Query


def _stage_atoms(leaves, query: Query, stage_schemas: dict[str, tuple[str, ...]]):
    atoms = []
    for leaf in leaves:
        if isinstance(leaf, Atom):
            atoms.append(leaf)
        else:
            atoms.append(Atom(leaf, stage_schemas[leaf]))
    return atoms


def _decompose(plan_tree: BinaryPlan | Atom):
    """Stages of a plan tree; a bare Atom (single-atom query) is its own
    root stage."""
    if isinstance(plan_tree, Atom):
        return [("__root", [plan_tree])]
    return plan_tree.decompose()


def _run_stages(
    query: Query,
    relations: dict[str, Relation],
    plan_tree: BinaryPlan,
    *,
    fj_mode: str,
    factorize: bool,
    dynamic_cover: bool,
    agg,
    stats: engine.ExecStats | None,
):
    rels = dict(relations)
    stage_schemas: dict[str, tuple[str, ...]] = {}
    stages = _decompose(plan_tree)
    result = None
    for name, leaves in stages:
        atoms = _stage_atoms(leaves, query, stage_schemas)
        sub_q = Query(atoms)
        fj = binary2fj(atoms, sub_q)
        if factorize:
            fj = factor(fj)
        modes = _trie_modes(fj, fj_mode)
        is_root = name == "__root"
        out = engine.execute(
            fj,
            rels,
            mode=modes,
            dynamic_cover=dynamic_cover and factorize,
            agg=agg if is_root else None,
            stats=stats,
        )
        if is_root:
            result = out
        else:
            bound, mult = out
            cols = engine.materialize(bound, mult, sub_q.head)
            rels[name] = Relation(name, cols)
            stage_schemas[name] = sub_q.head
    return result


def _trie_modes(fj: FreeJoinPlan, fj_mode: str) -> dict[str, str]:
    """Per-relation trie mode. For the binary-join baseline ("binary"):
    hash tables are built eagerly for every probed relation, while pure
    covers (only iterated, single level) build nothing."""
    parts = fj.partitions()
    if fj_mode != "binary":
        return {a: fj_mode for a in parts}
    probed = set()
    for k, node in enumerate(fj.nodes):
        for sa in node[1:]:
            if sa.vars:
                probed.add(sa.alias)
    return {a: ("simple" if a in probed else "colt") for a in parts}


def free_join(
    query: Query,
    relations: dict[str, Relation],
    plan_tree: BinaryPlan | None = None,
    *,
    mode: str = "colt",
    agg: str | None = None,
    dynamic_cover: bool = True,
    stats: engine.ExecStats | None = None,
    compiled: bool = False,
):
    """The full Free Join system: cost-based binary plan -> binary2fj ->
    factor -> COLT + vectorized execution (the paper's Sec 5 configuration).

    compiled=True instead runs the root stage on the static-shape executor
    with planner-derived capacities (mode/dynamic_cover/stats apply to the
    eager path only)."""
    if compiled:
        return compiled_free_join(query, relations, plan_tree, agg=agg)
    if plan_tree is None:
        plan_tree = optimize(query, relations)
    return _run_stages(
        query,
        relations,
        plan_tree,
        fj_mode=mode,
        factorize=True,
        dynamic_cover=dynamic_cover,
        agg=agg,
        stats=stats,
    )


def compiled_free_join(
    query: Query,
    relations: dict[str, Relation],
    plan_tree: BinaryPlan | Atom | None = None,
    *,
    agg: str | None = "count",
    impl: str = "jnp",
    budget: int = 32,
    safety: float = 2.0,
    compact_threshold: float = 0.25,
    jit: bool = True,
    info: dict | None = None,
):
    """Compiled driver, no manual capacities (see module docstring).

    One planning pass serves the whole query: a single optimizer.Stats cache
    (one np.unique per referenced column) feeds optimize and
    plan_capacities, and the StaticSchedule computed by the planner rides on
    the CapacityPlan into every executor build. Zero-row inputs run through
    the executor natively (an empty relation is a trie whose every frontier
    expansion yields zero live lanes) — no host-side gate.

    Non-root stages of a bushy plan are materialized eagerly; the root stage
    runs on compiled.AdaptiveExecutor sized by capacity.plan_capacities.
    Returns the eager contract: a count for agg="count", else (bound, mult)
    over live rows. `info`, if given, receives the runner, capacity plan,
    and retry counters for inspection."""
    from repro.core.capacity import plan_capacities
    from repro.core.compiled import AdaptiveExecutor

    rels = dict(relations)
    stats = Stats(rels)  # live view: sees stage relations as they land
    if plan_tree is None:
        plan_tree = optimize(query, rels, stats=stats)
    stage_schemas: dict[str, tuple[str, ...]] = {}
    stages = _decompose(plan_tree)
    for name, leaves in stages[:-1]:  # non-root stages: eager materialization
        atoms = _stage_atoms(leaves, query, stage_schemas)
        sub_q = Query(atoms)
        fj = factor(binary2fj(atoms, sub_q))
        bound, mult = engine.execute(fj, rels, mode=_trie_modes(fj, "colt"), agg=None)
        rels[name] = Relation(name, engine.materialize(bound, mult, sub_q.head))
        stage_schemas[name] = sub_q.head
    _, leaves = stages[-1]
    atoms = _stage_atoms(leaves, query, stage_schemas)
    sub_q = Query(atoms)
    fj = factor(binary2fj(atoms, sub_q))
    cap_plan = plan_capacities(
        fj, stats=stats, safety=safety, compact_threshold=compact_threshold
    )
    runner = AdaptiveExecutor(fj, cap_plan, impl=impl, budget=budget, agg=agg, jit=jit)
    out = runner.run_relations(rels)
    if info is not None:
        info.update(
            runner=runner,
            cap_plan=runner.cap_plan,
            retries=runner.retries,
            compiles=runner.compiles,
        )
    return out


def binary_join(
    query: Query,
    relations: dict[str, Relation],
    plan_tree: BinaryPlan | None = None,
    *,
    agg: str | None = None,
    stats: engine.ExecStats | None = None,
):
    """Baseline 1: classic binary hash join == the unfactored binary2fj plan
    with eagerly-built hash tables (Sec 5.3: 'if we do not optimize the Free
    Join plan ... Free Join would behave identically to binary join')."""
    if plan_tree is None:
        plan_tree = optimize(query, relations)
    return _run_stages(
        query,
        relations,
        plan_tree,
        fj_mode="binary",
        factorize=False,
        dynamic_cover=False,
        agg=agg,
        stats=stats,
    )


def generic_join(
    query: Query,
    relations: dict[str, Relation],
    var_order: list[str] | None = None,
    plan_tree: BinaryPlan | None = None,
    *,
    agg: str | None = None,
    stats: engine.ExecStats | None = None,
):
    """Baseline 2: Generic Join — full trie construction for every relation,
    variable-at-a-time plan. Variable order defaults to the one induced by
    the Free Join plan (Sec 5.1)."""
    if var_order is None:
        if plan_tree is None:
            plan_tree = optimize(query, relations)
        order: list[str] = []
        stage_schemas: dict[str, tuple[str, ...]] = {}
        for name, leaves in _decompose(plan_tree):
            atoms = _stage_atoms(leaves, query, stage_schemas)
            sub_q = Query(atoms)
            fj = factor(binary2fj(atoms, sub_q))
            stage_schemas[name] = sub_q.head
            for v in var_order_from_fj(fj):
                if v not in order:
                    order.append(v)
        var_order = [v for v in order if v in query.variables]
    plan = gj_plan(query, var_order)
    out = engine.execute(plan, relations, mode="simple", dynamic_cover=True, agg=agg, stats=stats)
    return out


def to_sorted_tuples(result, head) -> list:
    bound, mult = result
    cols = engine.materialize(bound, mult, head)
    arrs = [np.asarray(cols[v]) for v in head]
    n = len(arrs[0]) if arrs else 0
    return sorted(tuple(int(a[i]) for a in arrs) for i in range(n))
