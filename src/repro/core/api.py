"""Top-level drivers: Free Join, Generic Join, and binary hash join.

Each driver takes a query, relations, and a binary plan (tree). Bushy plans
are decomposed into left-deep stages (Sec 2.2). The eager drivers
materialize every non-root stage into a fresh host relation before its
parent runs — the paper's (intentionally simple) materialization strategy.

`free_join(compiled=True)` (or `compiled_free_join`) instead runs the
*whole* stage chain as one on-device program: query -> cost-based binary
plan -> per-stage binary2fj + factor -> capacity.plan_chain_capacities ->
one compiled.AdaptiveExecutor call. Non-root stages execute with the same
static-shape executor as the root (agg=None), their output columns stay on
device as padded/mult-weighted buffers, and the next stage builds its trie
straight from that buffer — no host round-trips, no eager engine anywhere
in the compiled path. No manual capacities — per-stage buffer sizes come
from the optimizer's estimates (stage output estimates feeding downstream
stages) capped by the AGM bound, and any stage's overflow is recovered by
growing exactly the offending node and re-running the chain.

`chain_stages=False` keeps the previous hybrid (non-root stages eager on
the host, root compiled) as a reference/benchmark baseline.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, fields, replace

import numpy as np

from repro.core import engine, faults, membudget, relcache
from repro.core.plan import (
    BinaryPlan,
    FreeJoinPlan,
    decompose_tree,
    gj_plan,
    stage_plans,
    var_order_from_fj,
)
from repro.core.optimizer import JoinOrderOptimizer, Stats, optimize
from repro.relational.relation import Relation
from repro.relational.schema import Atom, Query


@dataclass(frozen=True)
class ExecOptions:
    """Execution knobs of the compiled path, as one frozen (hashable)
    value: it rides through the runner-cache key, the serving engine's
    template keys, and every planner/executor build, replacing the loose
    kwarg set compiled_free_join used to take.

    impl: kernel implementation ("jnp" | "pallas_interpret" | "pallas");
    budget: hash-probe displacement budget; safety: multiplier on planner
    cardinality estimates; compact_threshold: schedule compaction when the
    live fraction is estimated to drop below this; jit: jax.jit the
    executor; chain_stages: run every stage of a bushy plan on device
    (False = the hybrid reference baseline); optimize_level: plan-choice
    effort when no plan tree is given — 0 is the greedy left-deep search,
    1 (default) enumerates bushy candidates by dynamic programming, ranks
    them with the device cost model under the standard budget, and pins
    the winner for the life of the relations, 2 raises the enumeration
    budget to exhaustive and re-plans when measured cardinalities
    contradict the estimates (see optimizer.JoinOrderOptimizer);
    verify: run the static plan verifier (repro.analysis.planlint) over
    the derived stage chain and capacity plan BEFORE compiling — raises
    analysis.PlanVerificationError listing every violated invariant
    instead of failing opaquely inside a jit trace. Off by default (the
    planner's own output is verified in CI); turn it on when feeding
    hand-built plans or debugging a planner change."""

    impl: str = "jnp"
    budget: int = 32
    safety: float = 2.0
    compact_threshold: float = 0.25
    jit: bool = True
    chain_stages: bool = True
    optimize_level: int = 1
    verify: bool = False


# one release of backwards compatibility: compiled_free_join's old loose
# kwargs still work but warn (collapse them into ExecOptions)
_LEGACY_OPTION_KWARGS = tuple(f.name for f in fields(ExecOptions))


def _resolve_options(options: ExecOptions | None, legacy: dict) -> ExecOptions:
    given = {k: v for k, v in legacy.items() if v is not None}
    if given:
        warnings.warn(
            f"passing {sorted(given)} as loose kwargs is deprecated; "
            "pass options=ExecOptions(...) instead",
            DeprecationWarning,
            stacklevel=3,
        )
    return replace(options or ExecOptions(), **given)


# stage derivation lives in core/plan.py (the optimizer's device cost model
# needs it too); the old private names stay importable
_decompose = decompose_tree
_stage_plans = stage_plans


def _run_stages(
    query: Query,
    relations: dict[str, Relation],
    plan_tree: BinaryPlan,
    *,
    fj_mode: str,
    factorize: bool,
    dynamic_cover: bool,
    agg,
    stats: engine.ExecStats | None,
):
    """Eager stage driver: every stage runs on the numpy engine, non-root
    stage outputs are materialized into fresh host relations. The compiled
    driver (compiled_free_join) shares _stage_plans but routes *all* stages
    through the static-shape executor instead."""
    rels = dict(relations)
    result = None
    for name, fj in _stage_plans(query, plan_tree, factorize=factorize):
        modes = _trie_modes(fj, fj_mode)
        is_root = name == "__root"
        out = engine.execute(
            fj,
            rels,
            mode=modes,
            dynamic_cover=dynamic_cover and factorize,
            agg=agg if is_root else None,
            stats=stats,
        )
        if is_root:
            result = out
        else:
            bound, mult = out
            cols = engine.materialize(bound, mult, fj.query.head)
            rels[name] = Relation(name, cols)
    return result


def _trie_modes(fj: FreeJoinPlan, fj_mode: str) -> dict[str, str]:
    """Per-relation trie mode. For the binary-join baseline ("binary"):
    hash tables are built eagerly for every probed relation, while pure
    covers (only iterated, single level) build nothing."""
    parts = fj.partitions()
    if fj_mode != "binary":
        return {a: fj_mode for a in parts}
    probed = set()
    for node in fj.nodes:
        for sa in node[1:]:
            if sa.vars:
                probed.add(sa.alias)
    return {a: ("simple" if a in probed else "colt") for a in parts}


def _apply_filters_eager(
    query: Query, relations: dict[str, Relation], filters: dict[str, int]
) -> dict[str, Relation]:
    """Eager-path equality selections: every atom containing a filtered var
    is pre-selected to the rows matching the constant (joins equate the var
    across atoms, so this is exactly sigma_{v=c} of the query result)."""
    unknown = set(filters) - set(query.variables)
    if unknown:
        raise ValueError(f"filter vars not in the query: {sorted(unknown)}")
    rels = dict(relations)
    for a in query.atoms:
        sel = [v for v in a.vars if v in filters]
        if not sel:
            continue
        rel = rels[a.alias]
        mask = np.ones(rel.num_rows, bool)
        for v in sel:
            mask &= rel.columns[v] == filters[v]
        rels[a.alias] = rel.select(mask)
    return rels


def free_join(
    query: Query,
    relations: dict[str, Relation],
    plan_tree: BinaryPlan | None = None,
    *,
    mode: str = "colt",
    agg: str | None = None,
    dynamic_cover: bool = True,
    stats: engine.ExecStats | None = None,
    compiled: bool = False,
    filters: dict[str, int] | None = None,
    options: ExecOptions | None = None,
):
    """The full Free Join system: cost-based binary plan -> binary2fj ->
    factor -> COLT + vectorized execution (the paper's Sec 5 configuration).

    compiled=True instead runs the whole plan on the static-shape executor
    with planner-derived capacities (see compiled_free_join, which also
    accepts `options`). The eager-only knobs are rejected loudly on the
    compiled path — `mode` and `dynamic_cover` have no compiled equivalent
    and `stats` (engine.ExecStats) measures the eager engine; silently
    dropping them would misreport what ran. Use compiled_free_join's
    `info` dict for compiled-path introspection.

    filters: equality selections {var: constant}, applied on either path
    (sigma_{v=c} over the join result). options: compiled-path ExecOptions
    (invalid on the eager path)."""
    if compiled:
        dropped = []
        if mode != "colt":
            dropped.append(f"mode={mode!r}")
        if dynamic_cover is not True:
            dropped.append(f"dynamic_cover={dynamic_cover!r}")
        if stats is not None:
            dropped.append("stats (use compiled_free_join(info=...) instead)")
        if dropped:
            raise ValueError(
                "free_join(compiled=True) does not honor the eager-path "
                "arguments " + ", ".join(dropped)
            )
        return compiled_free_join(
            query, relations, plan_tree, agg=agg, filters=filters, options=options
        )
    if options is not None:
        raise ValueError("options=ExecOptions(...) applies to the compiled path only")
    if filters:
        relations = _apply_filters_eager(query, relations, filters)
    if plan_tree is None:
        plan_tree = optimize(query, relations)
    return _run_stages(
        query,
        relations,
        plan_tree,
        fj_mode=mode,
        factorize=True,
        dynamic_cover=dynamic_cover,
        agg=agg,
        stats=stats,
    )


# warm serving surface: whole AdaptiveExecutors reused across
# compiled_free_join calls, keyed by the query/plan structure + execution
# knobs + the identity of every base relation. Entries are evicted when any
# keyed relation dies (weakref finalizers — see relcache.KeyedCache), so an
# id() reused by a new relation object can never resurrect a stale runner.
_runner_cache = relcache.KeyedCache(max_entries=32)


def _govern_runner(cache, key, runner) -> None:
    """Register a freshly-cached runner with the device-memory governor,
    costed at its frontier footprint. The governor may LRU-evict it later
    (the callback drops the cache entry; an identical query then re-plans),
    and the cache's own eviction paths release the governor entry through
    KeyedCache.on_evict — the two stores can never disagree. A shed (the
    runner alone cannot fit the budget) un-caches it: the current call
    still runs, nothing ungoverned is kept warm."""
    if isinstance(cache, relcache.ScopedCache):
        root, fkey = cache._parent, (cache._tag, key)
    else:
        root, fkey = cache, key
    if root.on_evict is None:
        root.on_evict = lambda k, _v, _root=root: membudget.GOVERNOR.release(
            ("runner", id(_root), k)
        )
    token = ("runner", id(root), fkey)
    try:
        membudget.GOVERNOR.account(
            token,
            runner.frontier_nbytes(),
            evict=lambda _root=root, _k=fkey: _root._evict(_k),
        )
    except membudget.MemoryBudgetError:
        root._evict(fkey)
        return
    runner._govern_token = token


def _runner_key(stages, rels, base, agg, options, filter_vars, batch, max_capacity):
    return (
        # str(plan) renders the nodes but not the output projection, and
        # agg=None executors bind exactly plan.query.head — so the head is
        # part of the executor's identity
        tuple((name, str(p), tuple(p.query.head)) for name, p in stages),
        agg,
        options,
        filter_vars,
        batch,
        max_capacity,
        tuple(sorted((a, id(rels[a])) for a in base)),
    )


def _acquire_runner(
    query: Query,
    relations: dict[str, Relation],
    plan_tree,
    *,
    agg: str | None,
    options: ExecOptions,
    filter_vars: tuple[str, ...] = (),
    batch: int | None = None,
    max_capacity: int | None = None,
    cache=None,
):
    """One planning pass -> one (possibly cached) AdaptiveExecutor.

    The shared runner-acquisition surface behind compiled_free_join AND the
    join serving engine: a single optimizer.Stats cache feeds optimize and
    plan_chain_capacities, the StaticSchedule per stage rides on its
    CapacityPlan into every executor build, and the whole runner is keyed
    in the runner cache by plan structure + head + options + filter vars +
    batch width + relation identities. `filter_vars` builds a
    constant-parameterized executor (capacities planned with
    FilteredStats, sized for the selected slice); `batch` builds the
    vmapped multi-lane variant; `max_capacity` arms the per-node growth
    quota (admission control). `cache` defaults to the verbatim runner
    cache — the serving engine passes its template-scoped namespace.

    Returns (runner, rels, cacheable, plan_tree): rels is the relation
    dict the runner should execute over (the hybrid baseline materializes
    its eager stages into it), cacheable=False marks hybrid multi-stage
    runs whose per-call stage relations make caching useless, and
    plan_tree is the binary plan actually chosen (the caller's, or the
    optimizer's — exposed so callers can observe feedback-driven
    re-planning)."""
    from repro.core.capacity import plan_chain_capacities
    from repro.core.compiled import AdaptiveExecutor, _base_aliases
    from repro.core.optimizer import FilteredStats

    cache = _runner_cache if cache is None else cache
    rels = dict(relations)
    stats = Stats(rels, cached=True)  # live view + registry-backed distincts
    if plan_tree is None:
        # cost-based choice with the measured-cardinality feedback loop: a
        # warm query whose first run contradicted the estimates re-plans
        # here (the new plan keys a new runner; the choice itself is
        # memoized against the feedback store's version, so steady state
        # pays one cache probe)
        plan_tree = JoinOrderOptimizer(
            level=options.optimize_level,
            safety=options.safety,
            compact_threshold=options.compact_threshold,
            feedback=relcache.FEEDBACK,
        ).choose(query, rels, stats=stats)
    stages = _stage_plans(query, plan_tree)
    # the hybrid path materializes fresh stage relations per call — a cache
    # entry keyed on them could never hit (and its put would evict a live
    # runner), so don't store one
    cacheable = options.chain_stages or len(stages) == 1
    if not options.chain_stages and len(stages) > 1:
        if filter_vars:
            raise ValueError("filters require chain_stages=True (the hybrid "
                             "baseline's eager stages cannot parameterize constants)")
        # hybrid baseline: non-root stages eager on the host, root compiled
        for name, fj in stages[:-1]:
            bound, mult = engine.execute(fj, rels, mode=_trie_modes(fj, "colt"), agg=None)
            rels[name] = Relation(name, engine.materialize(bound, mult, fj.query.head))
        stages = stages[-1:]
    base = sorted(_base_aliases(stages))
    key = _runner_key(stages, rels, base, agg, options, filter_vars, batch, max_capacity)
    runner = cache.get(key) if cacheable else None
    if runner is None:
        pstats = stats
        if filter_vars and batch is None:
            # kill-mode filters prune the frontier as they apply, so
            # capacity-plan for the selected slice, not the whole relation:
            # depends only on WHICH vars are filtered (never the constants),
            # so the plan is shared by every query of the template. optimize
            # above stays unfiltered for the same template-stability reason.
            # Batched (mask-mode) runners keep the UNfiltered frontier
            # layout — shared across lanes — so plain stats size them right.
            pstats = FilteredStats(
                stats,
                {a.alias: frozenset(v for v in a.vars if v in filter_vars)
                 for a in query.atoms},
            )
        cap_plan = plan_chain_capacities(
            stages,
            stats=pstats,
            safety=options.safety,
            compact_threshold=options.compact_threshold,
            feedback=relcache.FEEDBACK,
        )
        if options.verify:
            # full pre-compile verification: plan structure, schedules,
            # capacities, stage DAG, filter coverage — findings raised as
            # one PlanVerificationError instead of a crash mid-trace
            from repro.analysis.planlint import lint_chain

            lint_chain(
                stages, cap_plan, filter_vars=filter_vars, batch=batch
            ).raise_errors()
        if len(stages) == 1:  # classic single-stage surface (plain CapacityPlan)
            cap_plan = cap_plan.stages[0]
        plan_arg = stages[0][1] if len(stages) == 1 else tuple(stages)
        runner = AdaptiveExecutor(
            plan_arg,
            cap_plan,
            impl=options.impl,
            budget=options.budget,
            agg=agg,
            jit=options.jit,
            tighten=True,
            filter_vars=filter_vars,
            batch=batch,
            max_capacity=max_capacity,
        )
        if cacheable:
            cache.put(key, runner, [rels[a] for a in base])
            _govern_runner(cache, key, runner)
    return runner, rels, cacheable, plan_tree


def compiled_free_join(
    query: Query,
    relations: dict[str, Relation],
    plan_tree: BinaryPlan | Atom | None = None,
    *,
    agg: str | None = "count",
    options: ExecOptions | None = None,
    filters: dict[str, int] | None = None,
    info: dict | None = None,
    impl: str | None = None,
    budget: int | None = None,
    safety: float | None = None,
    compact_threshold: float | None = None,
    jit: bool | None = None,
    chain_stages: bool | None = None,
):
    """Compiled driver, no manual capacities (see module docstring).

    Execution knobs ride in `options` (ExecOptions); the old loose kwargs
    (impl/budget/safety/compact_threshold/jit/chain_stages) still work for
    one release behind a DeprecationWarning. Zero-row inputs run through
    the executor natively (an empty relation is a trie whose every frontier
    expansion yields zero live lanes) — no host-side gate.

    Repeated calls over the same relation objects are the steady-state
    serving path and pay probe cost only: distinct counts persist in the
    per-relation registry (Stats(cached=True)), base tries come from the
    cross-call compiled.TRIE_CACHE, and the whole runner — capacity plan,
    learned growth, compiled executors — is reused from _runner_cache, so
    a warm call performs zero np.unique, zero trie builds, zero
    build_table calls, and zero recompiles.

    `filters` ({var: constant}) runs the query under equality selections
    through a constant-parameterized executor: the constants are runtime
    inputs, so every call with the same filtered VARS — whatever the
    constants — reuses one compiled runner. (The multi-query batched
    surface over the same machinery is serve.JoinServeEngine.)

    Every stage of a bushy plan — not just the root — runs on the
    static-shape executor, chained on device inside one
    compiled.AdaptiveExecutor call (see compiled.make_chain_executor);
    ExecOptions(chain_stages=False) restores the previous hybrid (non-root
    stages on the eager host engine) as a reference baseline. Returns the
    eager contract: a count for agg="count", else (bound, mult) over live
    rows. `info`, if given, receives the runner, capacity plan, retry
    counters, and the chosen plan tree (`plan_tree`) for inspection —
    compare plan_tree across calls to watch measured-cardinality feedback
    re-plan a misestimated query."""
    opts = _resolve_options(
        options,
        dict(impl=impl, budget=budget, safety=safety,
             compact_threshold=compact_threshold, jit=jit, chain_stages=chain_stages),
    )
    filters = dict(filters or {})
    unknown = set(filters) - set(query.variables)
    if unknown:
        raise ValueError(f"filter vars not in the query: {sorted(unknown)}")
    filter_vars = tuple(sorted(filters))
    runner, rels, cacheable, chosen_tree = _acquire_runner(
        query, relations, plan_tree, agg=agg, options=opts, filter_vars=filter_vars
    )
    consts = (
        np.asarray([filters[v] for v in filter_vars], np.int32) if filter_vars else None
    )
    # the hybrid baseline's stage relations are fresh every call — skip the
    # trie cache entirely there (in-graph builds ARE its per-call cost;
    # caching would only insert dead-on-arrival entries)
    degraded = None
    try:
        out = runner.run_relations(rels, reuse_tries=cacheable, filter_consts=consts)
    except Exception as e:
        # the degradation ladder's bottom rung for the standalone surface:
        # compile failure, device OOM, or a governor shed answers eagerly
        # on the host instead of raising — the result contract (count /
        # (bound, mult)) is the eager engine's own
        if not faults.recoverable(e):
            raise
        warnings.warn(
            f"compiled path degraded to eager free_join after "
            f"{type(e).__name__}: {e}",
            RuntimeWarning,
            stacklevel=2,
        )
        degraded = f"{type(e).__name__}: {e}"
        tree = chosen_tree if isinstance(chosen_tree, BinaryPlan) else None
        live = {a: relcache.live_relation(r) for a, r in relations.items()}
        out = free_join(query, live, tree, agg=agg, filters=filters or None)
    if info is not None:
        info.update(
            runner=runner,
            cap_plan=runner.cap_plan,
            retries=runner.retries,
            compiles=runner.compiles,
            options=opts,
            plan_tree=chosen_tree,
        )
        if degraded is not None:
            info.update(degraded_to="eager", degraded_from=degraded)
    return out


def binary_join(
    query: Query,
    relations: dict[str, Relation],
    plan_tree: BinaryPlan | None = None,
    *,
    agg: str | None = None,
    stats: engine.ExecStats | None = None,
):
    """Baseline 1: classic binary hash join == the unfactored binary2fj plan
    with eagerly-built hash tables (Sec 5.3: 'if we do not optimize the Free
    Join plan ... Free Join would behave identically to binary join')."""
    if plan_tree is None:
        plan_tree = optimize(query, relations)
    return _run_stages(
        query,
        relations,
        plan_tree,
        fj_mode="binary",
        factorize=False,
        dynamic_cover=False,
        agg=agg,
        stats=stats,
    )


def generic_join(
    query: Query,
    relations: dict[str, Relation],
    var_order: list[str] | None = None,
    plan_tree: BinaryPlan | None = None,
    *,
    agg: str | None = None,
    stats: engine.ExecStats | None = None,
):
    """Baseline 2: Generic Join — full trie construction for every relation,
    variable-at-a-time plan. Variable order defaults to the one induced by
    the Free Join plan (Sec 5.1)."""
    if var_order is None:
        if plan_tree is None:
            plan_tree = optimize(query, relations)
        order: list[str] = []
        for _name, fj in _stage_plans(query, plan_tree):
            for v in var_order_from_fj(fj):
                if v not in order:
                    order.append(v)
        var_order = [v for v in order if v in query.variables]
    plan = gj_plan(query, var_order)
    return engine.execute(
        plan, relations, mode="simple", dynamic_cover=True, agg=agg, stats=stats
    )


def to_sorted_tuples(result, head) -> list:
    bound, mult = result
    cols = engine.materialize(bound, mult, head)
    arrs = [np.asarray(cols[v]) for v in head]
    n = len(arrs[0]) if arrs else 0
    return sorted(tuple(int(a[i]) for a in arrs) for i in range(n))
