"""Top-level drivers: Free Join, Generic Join, and binary hash join.

Each driver takes a query, relations, and a binary plan (tree). Bushy plans
are decomposed into left-deep stages (Sec 2.2). The eager drivers
materialize every non-root stage into a fresh host relation before its
parent runs — the paper's (intentionally simple) materialization strategy.

`free_join(compiled=True)` (or `compiled_free_join`) instead runs the
*whole* stage chain as one on-device program: query -> cost-based binary
plan -> per-stage binary2fj + factor -> capacity.plan_chain_capacities ->
one compiled.AdaptiveExecutor call. Non-root stages execute with the same
static-shape executor as the root (agg=None), their output columns stay on
device as padded/mult-weighted buffers, and the next stage builds its trie
straight from that buffer — no host round-trips, no eager engine anywhere
in the compiled path. No manual capacities — per-stage buffer sizes come
from the optimizer's estimates (stage output estimates feeding downstream
stages) capped by the AGM bound, and any stage's overflow is recovered by
growing exactly the offending node and re-running the chain.

`chain_stages=False` keeps the previous hybrid (non-root stages eager on
the host, root compiled) as a reference/benchmark baseline.
"""
from __future__ import annotations

import numpy as np

from repro.core import engine, relcache
from repro.core.plan import (
    BinaryPlan,
    FreeJoinPlan,
    binary2fj,
    factor,
    gj_plan,
    var_order_from_fj,
)
from repro.core.optimizer import Stats, optimize
from repro.relational.relation import Relation
from repro.relational.schema import Atom, Query


def _stage_atoms(leaves, query: Query, stage_schemas: dict[str, tuple[str, ...]]):
    atoms = []
    for leaf in leaves:
        if isinstance(leaf, Atom):
            atoms.append(leaf)
        else:
            atoms.append(Atom(leaf, stage_schemas[leaf]))
    return atoms


def _decompose(plan_tree: BinaryPlan | Atom):
    """Stages of a plan tree; a bare Atom (single-atom query) is its own
    root stage."""
    if isinstance(plan_tree, Atom):
        return [("__root", [plan_tree])]
    return plan_tree.decompose()


def _stage_plans(query: Query, plan_tree, *, factorize: bool = True):
    """Per-stage Free Join plans of a (possibly bushy) binary plan tree:
    [(name, fj_plan)], root last. Each stage's plan is built over its own
    sub-query (fj.query), whose head is the stage's output schema; later
    stages reference earlier ones by name as ordinary atoms."""
    stage_schemas: dict[str, tuple[str, ...]] = {}
    out = []
    for name, leaves in _decompose(plan_tree):
        atoms = _stage_atoms(leaves, query, stage_schemas)
        sub_q = Query(atoms)
        fj = binary2fj(atoms, sub_q)
        if factorize:
            fj = factor(fj)
        stage_schemas[name] = sub_q.head
        out.append((name, fj))
    return out


def _run_stages(
    query: Query,
    relations: dict[str, Relation],
    plan_tree: BinaryPlan,
    *,
    fj_mode: str,
    factorize: bool,
    dynamic_cover: bool,
    agg,
    stats: engine.ExecStats | None,
):
    """Eager stage driver: every stage runs on the numpy engine, non-root
    stage outputs are materialized into fresh host relations. The compiled
    driver (compiled_free_join) shares _stage_plans but routes *all* stages
    through the static-shape executor instead."""
    rels = dict(relations)
    result = None
    for name, fj in _stage_plans(query, plan_tree, factorize=factorize):
        modes = _trie_modes(fj, fj_mode)
        is_root = name == "__root"
        out = engine.execute(
            fj,
            rels,
            mode=modes,
            dynamic_cover=dynamic_cover and factorize,
            agg=agg if is_root else None,
            stats=stats,
        )
        if is_root:
            result = out
        else:
            bound, mult = out
            cols = engine.materialize(bound, mult, fj.query.head)
            rels[name] = Relation(name, cols)
    return result


def _trie_modes(fj: FreeJoinPlan, fj_mode: str) -> dict[str, str]:
    """Per-relation trie mode. For the binary-join baseline ("binary"):
    hash tables are built eagerly for every probed relation, while pure
    covers (only iterated, single level) build nothing."""
    parts = fj.partitions()
    if fj_mode != "binary":
        return {a: fj_mode for a in parts}
    probed = set()
    for k, node in enumerate(fj.nodes):
        for sa in node[1:]:
            if sa.vars:
                probed.add(sa.alias)
    return {a: ("simple" if a in probed else "colt") for a in parts}


def free_join(
    query: Query,
    relations: dict[str, Relation],
    plan_tree: BinaryPlan | None = None,
    *,
    mode: str = "colt",
    agg: str | None = None,
    dynamic_cover: bool = True,
    stats: engine.ExecStats | None = None,
    compiled: bool = False,
):
    """The full Free Join system: cost-based binary plan -> binary2fj ->
    factor -> COLT + vectorized execution (the paper's Sec 5 configuration).

    compiled=True instead runs the root stage on the static-shape executor
    with planner-derived capacities (mode/dynamic_cover/stats apply to the
    eager path only)."""
    if compiled:
        return compiled_free_join(query, relations, plan_tree, agg=agg)
    if plan_tree is None:
        plan_tree = optimize(query, relations)
    return _run_stages(
        query,
        relations,
        plan_tree,
        fj_mode=mode,
        factorize=True,
        dynamic_cover=dynamic_cover,
        agg=agg,
        stats=stats,
    )


# warm serving surface: whole AdaptiveExecutors reused across
# compiled_free_join calls, keyed by the query/plan structure + execution
# knobs + the identity of every base relation. Entries are evicted when any
# keyed relation dies (weakref finalizers — see relcache.KeyedCache), so an
# id() reused by a new relation object can never resurrect a stale runner.
_runner_cache = relcache.KeyedCache(max_entries=32)


def _runner_key(stages, rels, base, agg, impl, budget, jit, safety, compact_threshold):
    return (
        # str(plan) renders the nodes but not the output projection, and
        # agg=None executors bind exactly plan.query.head — so the head is
        # part of the executor's identity
        tuple((name, str(p), tuple(p.query.head)) for name, p in stages),
        agg,
        impl,
        budget,
        jit,
        safety,
        compact_threshold,
        tuple(sorted((a, id(rels[a])) for a in base)),
    )


def compiled_free_join(
    query: Query,
    relations: dict[str, Relation],
    plan_tree: BinaryPlan | Atom | None = None,
    *,
    agg: str | None = "count",
    impl: str = "jnp",
    budget: int = 32,
    safety: float = 2.0,
    compact_threshold: float = 0.25,
    jit: bool = True,
    info: dict | None = None,
    chain_stages: bool = True,
):
    """Compiled driver, no manual capacities (see module docstring).

    One planning pass serves the whole query: a single optimizer.Stats cache
    feeds optimize and plan_chain_capacities, and the StaticSchedule
    computed per stage rides on its CapacityPlan into every executor build.
    Zero-row inputs run through the executor natively (an empty relation is
    a trie whose every frontier expansion yields zero live lanes) — no
    host-side gate.

    Repeated calls over the same relation objects are the steady-state
    serving path and pay probe cost only: distinct counts persist in the
    per-relation registry (Stats(cached=True)), base tries come from the
    cross-call compiled.TRIE_CACHE, and the whole runner — capacity plan,
    learned growth, compiled executors — is reused from _runner_cache, so
    a warm call performs zero np.unique, zero trie builds, zero
    build_table calls, and zero recompiles.

    Every stage of a bushy plan — not just the root — runs on the
    static-shape executor, chained on device inside one
    compiled.AdaptiveExecutor call (see compiled.make_chain_executor);
    `chain_stages=False` restores the previous hybrid (non-root stages on
    the eager host engine) as a reference baseline. Returns the eager
    contract: a count for agg="count", else (bound, mult) over live rows.
    `info`, if given, receives the runner, capacity plan, and retry
    counters for inspection."""
    from repro.core.capacity import plan_chain_capacities
    from repro.core.compiled import AdaptiveExecutor, _base_aliases

    rels = dict(relations)
    stats = Stats(rels, cached=True)  # live view + registry-backed distincts
    if plan_tree is None:
        plan_tree = optimize(query, rels, stats=stats)
    stages = _stage_plans(query, plan_tree)
    # the hybrid path materializes fresh stage relations per call — a cache
    # entry keyed on them could never hit (and its put would evict a live
    # runner), so don't store one
    cacheable = chain_stages or len(stages) == 1
    if not chain_stages and len(stages) > 1:
        # hybrid baseline: non-root stages eager on the host, root compiled
        for name, fj in stages[:-1]:
            bound, mult = engine.execute(fj, rels, mode=_trie_modes(fj, "colt"), agg=None)
            rels[name] = Relation(name, engine.materialize(bound, mult, fj.query.head))
        stages = stages[-1:]
    base = sorted(_base_aliases(stages))
    key = _runner_key(stages, rels, base, agg, impl, budget, jit, safety, compact_threshold)
    runner = _runner_cache.get(key) if cacheable else None
    if runner is None:
        cap_plan = plan_chain_capacities(
            stages, stats=stats, safety=safety, compact_threshold=compact_threshold
        )
        if len(stages) == 1:  # classic single-stage surface (plain CapacityPlan)
            cap_plan = cap_plan.stages[0]
        plan_arg = stages[0][1] if len(stages) == 1 else tuple(stages)
        runner = AdaptiveExecutor(
            plan_arg, cap_plan, impl=impl, budget=budget, agg=agg, jit=jit, tighten=True
        )
        if cacheable:
            _runner_cache.put(key, runner, [rels[a] for a in base])
    # the hybrid baseline's stage relations are fresh every call — skip the
    # trie cache entirely there (in-graph builds ARE its per-call cost;
    # caching would only insert dead-on-arrival entries)
    out = runner.run_relations(rels, reuse_tries=cacheable)
    if info is not None:
        info.update(
            runner=runner,
            cap_plan=runner.cap_plan,
            retries=runner.retries,
            compiles=runner.compiles,
        )
    return out


def binary_join(
    query: Query,
    relations: dict[str, Relation],
    plan_tree: BinaryPlan | None = None,
    *,
    agg: str | None = None,
    stats: engine.ExecStats | None = None,
):
    """Baseline 1: classic binary hash join == the unfactored binary2fj plan
    with eagerly-built hash tables (Sec 5.3: 'if we do not optimize the Free
    Join plan ... Free Join would behave identically to binary join')."""
    if plan_tree is None:
        plan_tree = optimize(query, relations)
    return _run_stages(
        query,
        relations,
        plan_tree,
        fj_mode="binary",
        factorize=False,
        dynamic_cover=False,
        agg=agg,
        stats=stats,
    )


def generic_join(
    query: Query,
    relations: dict[str, Relation],
    var_order: list[str] | None = None,
    plan_tree: BinaryPlan | None = None,
    *,
    agg: str | None = None,
    stats: engine.ExecStats | None = None,
):
    """Baseline 2: Generic Join — full trie construction for every relation,
    variable-at-a-time plan. Variable order defaults to the one induced by
    the Free Join plan (Sec 5.1)."""
    if var_order is None:
        if plan_tree is None:
            plan_tree = optimize(query, relations)
        order: list[str] = []
        for _name, fj in _stage_plans(query, plan_tree):
            for v in var_order_from_fj(fj):
                if v not in order:
                    order.append(v)
        var_order = [v for v in order if v in query.variables]
    plan = gj_plan(query, var_order)
    out = engine.execute(plan, relations, mode="simple", dynamic_cover=True, agg=agg, stats=stats)
    return out


def to_sorted_tuples(result, head) -> list:
    bound, mult = result
    cols = engine.materialize(bound, mult, head)
    arrs = [np.asarray(cols[v]) for v in head]
    n = len(arrs[0]) if arrs else 0
    return sorted(tuple(int(a[i]) for a in arrs) for i in range(n))
