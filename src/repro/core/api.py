"""Top-level drivers: Free Join, Generic Join, and binary hash join.

Each driver takes a query, relations, and a binary plan (tree). Bushy plans
are decomposed into left-deep stages (Sec 2.2); every non-root stage is
materialized into a fresh relation before its parent runs — the paper's
(intentionally simple) materialization strategy.
"""
from __future__ import annotations

import numpy as np

from repro.core import engine
from repro.core.plan import (
    BinaryPlan,
    FreeJoinPlan,
    binary2fj,
    factor,
    gj_plan,
    var_order_from_fj,
)
from repro.core.optimizer import optimize
from repro.relational.relation import Relation
from repro.relational.schema import Atom, Query


def _stage_atoms(leaves, query: Query, stage_schemas: dict[str, tuple[str, ...]]):
    atoms = []
    for leaf in leaves:
        if isinstance(leaf, Atom):
            atoms.append(leaf)
        else:
            atoms.append(Atom(leaf, stage_schemas[leaf]))
    return atoms


def _run_stages(
    query: Query,
    relations: dict[str, Relation],
    plan_tree: BinaryPlan,
    *,
    fj_mode: str,
    factorize: bool,
    dynamic_cover: bool,
    agg,
    stats: engine.ExecStats | None,
):
    rels = dict(relations)
    stage_schemas: dict[str, tuple[str, ...]] = {}
    stages = plan_tree.decompose()
    result = None
    for name, leaves in stages:
        atoms = _stage_atoms(leaves, query, stage_schemas)
        sub_q = Query(atoms)
        fj = binary2fj(atoms, sub_q)
        if factorize:
            fj = factor(fj)
        modes = _trie_modes(fj, fj_mode)
        is_root = name == "__root"
        out = engine.execute(
            fj,
            rels,
            mode=modes,
            dynamic_cover=dynamic_cover and factorize,
            agg=agg if is_root else None,
            stats=stats,
        )
        if is_root:
            result = out
        else:
            bound, mult = out
            cols = engine.materialize(bound, mult, sub_q.head)
            rels[name] = Relation(name, cols)
            stage_schemas[name] = sub_q.head
    return result


def _trie_modes(fj: FreeJoinPlan, fj_mode: str) -> dict[str, str]:
    """Per-relation trie mode. For the binary-join baseline ("binary"):
    hash tables are built eagerly for every probed relation, while pure
    covers (only iterated, single level) build nothing."""
    parts = fj.partitions()
    if fj_mode != "binary":
        return {a: fj_mode for a in parts}
    probed = set()
    for k, node in enumerate(fj.nodes):
        for sa in node[1:]:
            if sa.vars:
                probed.add(sa.alias)
    return {a: ("simple" if a in probed else "colt") for a in parts}


def free_join(
    query: Query,
    relations: dict[str, Relation],
    plan_tree: BinaryPlan | None = None,
    *,
    mode: str = "colt",
    agg: str | None = None,
    dynamic_cover: bool = True,
    stats: engine.ExecStats | None = None,
):
    """The full Free Join system: cost-based binary plan -> binary2fj ->
    factor -> COLT + vectorized execution (the paper's Sec 5 configuration)."""
    if plan_tree is None:
        plan_tree = optimize(query, relations)
    return _run_stages(
        query,
        relations,
        plan_tree,
        fj_mode=mode,
        factorize=True,
        dynamic_cover=dynamic_cover,
        agg=agg,
        stats=stats,
    )


def binary_join(
    query: Query,
    relations: dict[str, Relation],
    plan_tree: BinaryPlan | None = None,
    *,
    agg: str | None = None,
    stats: engine.ExecStats | None = None,
):
    """Baseline 1: classic binary hash join == the unfactored binary2fj plan
    with eagerly-built hash tables (Sec 5.3: 'if we do not optimize the Free
    Join plan ... Free Join would behave identically to binary join')."""
    if plan_tree is None:
        plan_tree = optimize(query, relations)
    return _run_stages(
        query,
        relations,
        plan_tree,
        fj_mode="binary",
        factorize=False,
        dynamic_cover=False,
        agg=agg,
        stats=stats,
    )


def generic_join(
    query: Query,
    relations: dict[str, Relation],
    var_order: list[str] | None = None,
    plan_tree: BinaryPlan | None = None,
    *,
    agg: str | None = None,
    stats: engine.ExecStats | None = None,
):
    """Baseline 2: Generic Join — full trie construction for every relation,
    variable-at-a-time plan. Variable order defaults to the one induced by
    the Free Join plan (Sec 5.1)."""
    if var_order is None:
        if plan_tree is None:
            plan_tree = optimize(query, relations)
        order: list[str] = []
        stage_schemas: dict[str, tuple[str, ...]] = {}
        for name, leaves in plan_tree.decompose():
            atoms = _stage_atoms(leaves, query, stage_schemas)
            sub_q = Query(atoms)
            fj = factor(binary2fj(atoms, sub_q))
            stage_schemas[name] = sub_q.head
            for v in var_order_from_fj(fj):
                if v not in order:
                    order.append(v)
        var_order = [v for v in order if v in query.variables]
    plan = gj_plan(query, var_order)
    out = engine.execute(plan, relations, mode="simple", dynamic_cover=True, agg=agg, stats=stats)
    return out


def to_sorted_tuples(result, head) -> list:
    bound, mult = result
    cols = engine.materialize(bound, mult, head)
    arrs = [np.asarray(cols[v]) for v in head]
    n = len(arrs[0]) if arrs else 0
    return sorted(tuple(int(a[i]) for a in arrs) for i in range(n))
