"""COLT: Column-Oriented Lazy Trie (Sec 4.2), vectorized.

The paper's COLT is a pointer tree whose leaves are vectors of row offsets
into column storage, and whose hash-map nodes materialize lazily on first
`get`. A pointer tree does not vectorize, so we flatten each trie *level*
into contiguous arrays:

  level d (forced):  unique (parent_group, key) pairs, stored as
                     parent[K], key_cols[K]; a vectorized open-addressing
                     table maps (parent, key) -> key-row; a CSR over parents
                     supports iteration. Key-row r at depth d IS group r at
                     depth d+1.
  leaf (unforced):   row offsets into the base columns, grouped by the
                     deepest forced level's groups (CSR). This is exactly
                     COLT's vector-of-offsets leaf, batched across all
                     sibling nodes of that depth.

Laziness: `force(depth, alive)` groups only the offsets whose parent group
is still alive in the current frontier — the vectorized analogue of COLT
materializing one sub-trie per probed key. Because every trie level is
consumed by exactly one Free Join plan node, a single filtered force per
level is exact. A relation that is only ever iterated at its last level
never builds anything (leaf identity; zero build cost for cover relations).

Variants (Fig. 17 ablation):
  mode="colt"   on-demand + alive-filtered forces (this paper)
  mode="slt"    level 0 forced eagerly, deeper levels on demand, unfiltered
                (simple lazy trie of Freitag et al. [7])
  mode="simple" all levels forced eagerly at build (classic Generic Join trie)
"""
from __future__ import annotations

import time

import numpy as np

from repro.relational.npkit import HashTable, csr_expand, group_by
from repro.relational.relation import Relation


class TrieLevel:
    """One forced trie depth: unique (parent, key) rows."""

    __slots__ = ("key_vars", "parent", "keys", "table", "koff", "num_keys")

    def __init__(self, key_vars, parent, keys, num_parents: int):
        self.key_vars = key_vars
        self.parent = parent  # (K,) sorted parent group ids
        self.keys = keys  # list per var, each (K,)
        self.table = HashTable([parent] + keys)
        # CSR: parent group -> contiguous key rows (parent-major lex order)
        self.koff = np.searchsorted(parent, np.arange(num_parents + 1)).astype(np.int64)
        self.num_keys = len(parent)


class Colt:
    """A lazily-built trie over one relation, shaped by its plan partition."""

    def __init__(
        self,
        rel: Relation,
        level_vars: list[tuple[str, ...]],
        mode: str = "colt",
        filtered: bool = True,
    ):
        assert mode in ("colt", "slt", "simple")
        self.rel = rel
        self.level_vars = level_vars  # [y_0, ..., y_{L-1}]
        self.L = len(level_vars)
        self.mode = mode
        # alive-filtered forcing is only exact when each level is consumed
        # once (full-batch engine); the tuple-at-a-time engine revisits
        # levels across recursive calls and must force whole levels.
        self.filtered = filtered and mode == "colt"
        self.levels: list[TrieLevel] = []  # forced depths 0..f-1
        # unforced leaf: rows grouped by depth-f groups. row_ids=None means
        # the identity [0..n) (no materialization — the base table itself).
        self.leaf_offsets = np.array([0, rel.num_rows], dtype=np.int64)
        self.leaf_rows: np.ndarray | None = None
        self.build_ns = 0  # build-time accounting for the ablation
        if mode == "simple":
            while self.forced_depth < self.L:
                self.force(self.forced_depth)
        elif mode == "slt" and self.L > 0:
            self.force(0)

    # -- introspection ----------------------------------------------------
    @property
    def forced_depth(self) -> int:
        return len(self.levels)

    def num_groups(self, depth: int) -> int:
        if depth == 0:
            return 1
        return self.levels[depth - 1].num_keys

    def key_count_estimate(self, depth: int) -> int:
        """Sec 4.4: # keys if forced, else the vector length as an estimate."""
        if depth < self.forced_depth:
            return self.levels[depth].num_keys
        return self.rel.num_rows if self.leaf_rows is None else len(self.leaf_rows)

    def iter_cost(self, depth: int, gids: np.ndarray) -> int:
        """Exact number of rows `iter_expand(depth, gids)` would produce —
        the frontier-conditional refinement of Sec 4.4's fewest-keys rule.
        The paper estimates with global key counts (all it can afford
        tuple-at-a-time); the vectorized engine can afford the exact
        per-subtrie sum, which avoids iterating a large unconsumed relation
        against a small frontier."""
        if depth < self.forced_depth:
            off = self.levels[depth].koff
            return int((off[gids + 1] - off[gids]).sum())
        if depth == self.forced_depth:
            off = self.leaf_offsets
            return int((off[gids + 1] - off[gids]).sum())
        raise ValueError("depth beyond frontier")

    def _rows_of(self, member: np.ndarray) -> np.ndarray:
        return member if self.leaf_rows is None else self.leaf_rows[member]

    # -- forcing ----------------------------------------------------------
    def force(self, depth: int, alive: np.ndarray | None = None) -> None:
        """Materialize trie depth `depth` (must equal forced_depth). With
        `alive` (sorted unique parent gids), only sub-tries of those parents
        are built — COLT's lazy expansion, batched."""
        t0 = time.perf_counter_ns()
        assert depth == self.forced_depth and depth < self.L
        ng = self.num_groups(depth)
        if alive is None or not self.filtered or len(alive) >= ng:
            # all groups alive (or unfiltered mode): group every row directly
            counts = np.diff(self.leaf_offsets)
            parent_of_row = np.repeat(np.arange(ng, dtype=np.int64), counts)
            rows = (
                np.arange(self.rel.num_rows, dtype=np.int64)
                if self.leaf_rows is None
                else self.leaf_rows
            )
        else:
            fr, member = csr_expand(self.leaf_offsets, alive)
            parent_of_row = alive[fr]
            rows = self._rows_of(member)
        key_cols = self.rel.gather(self.level_vars[depth], rows)
        uniq, _, order, offsets = group_by([parent_of_row] + key_cols)
        level = TrieLevel(
            self.level_vars[depth], uniq[0], uniq[1:], self.num_groups(depth)
        )
        self.levels.append(level)
        self.leaf_rows = rows[order]
        self.leaf_offsets = offsets
        self.build_ns += time.perf_counter_ns() - t0

    def _ensure(self, depth: int, alive_gids: np.ndarray) -> None:
        if depth >= self.forced_depth:
            alive = np.unique(alive_gids)
            self.force(depth, alive)

    # -- batched trie ops used by the engine -------------------------------
    def probe(self, depth: int, gids: np.ndarray, key_cols: list[np.ndarray]) -> np.ndarray:
        """Batched get(): (group at `depth`, key) -> group at depth+1, or -1."""
        self._ensure(depth, gids)
        return self.levels[depth].table.probe([gids] + list(key_cols))

    def iter_expand(self, depth: int, gids: np.ndarray):
        """Batched iter() over the sub-tries `gids` at `depth`.

        Returns (frontier_row_index, bound_cols, new_gids). If `depth` is the
        last level and unforced, iterates base rows directly (zero build) and
        new_gids is None (atom exhausted, multiplicity 1 per row). Otherwise
        iterates unique keys; new_gids index depth+1 groups.
        """
        if depth == self.L - 1 and depth >= self.forced_depth:
            fr, member = csr_expand(self.leaf_offsets, gids)
            rows = self._rows_of(member)
            cols = self.rel.gather(self.level_vars[depth], rows)
            return fr, cols, None
        self._ensure(depth, gids)
        lvl = self.levels[depth]
        fr, krow = csr_expand(lvl.koff, gids)
        cols = [k[krow] for k in lvl.keys]
        return fr, cols, krow

    def leaf_counts(self, gids: np.ndarray) -> np.ndarray:
        """Bag multiplicity below each depth-L group (duplicate tuples)."""
        return self.leaf_offsets[gids + 1] - self.leaf_offsets[gids]

    def subtree_sizes(self, depth: int, gids: np.ndarray) -> np.ndarray:
        """Number of base rows below each group at `depth` == the product of
        all remaining enumerations (used for factorized counting)."""
        if depth == self.forced_depth:
            return self.leaf_offsets[gids + 1] - self.leaf_offsets[gids]
        raise ValueError("subtree_sizes only available at the unforced frontier")
