"""Global device-memory governor for the compiled path's caches.

Every persistent device buffer the serving stack keeps warm — cached
StaticTries (compiled.TRIE_CACHE), cached AdaptiveExecutors and their
frontier capacity vectors (api._runner_cache) — is accounted here against
one configurable budget. Without a budget (the default) the governor is
pure bookkeeping: `live_bytes` is observable, nothing is ever refused.
With a budget set (`set_budget` / the `budget()` context manager) the
governor enforces a hard invariant the chaos suite locks:

    governed live bytes never exceed the budget.

Enforcement has two teeth:

* **LRU eviction of cold entries.** Every accounted entry carries an
  evict callback that drops it from its home cache (a trie namespace
  entry, a runner-cache slot). When a new/updated entry needs room, the
  least-recently-touched entries are evicted until it fits.
* **Admission shedding.** When evicting everything else still cannot make
  room — the entry alone is bigger than the budget — `account` raises
  MemoryBudgetError *without* registering the entry. Callers shed: the
  trie cache serves the trie uncached, the runner cache declines to keep
  the runner, and a runner whose adaptive GROWTH would blow the budget
  propagates the error into the serving engine's degradation ladder
  (halve the batch -> unbatched -> eager), so the query still answers.

Entries die three ways, all releasing their bytes: governor eviction
(the callback removes them from their cache), explicit `release` (the
home cache dropped them first — KeyedCache.on_evict wires this), or
their owner relation being garbage collected (a weakref.finalize per
owned token). Tokens embed `id(owner)`, which is safe for the same
reason relcache.KeyedCache keys are: the finalizer releases the token
before the id can be reused.
"""
from __future__ import annotations

import contextlib
import weakref
from collections import OrderedDict


class MemoryBudgetError(RuntimeError):
    """Admitting/growing a governed buffer would exceed the device-memory
    budget even after evicting every cold entry. Carries the arithmetic so
    callers (and the degradation ladder) can report it."""

    def __init__(self, requested: int, live: int, budget: int):
        super().__init__(
            f"device-memory budget exceeded: need {requested} bytes with "
            f"{live} live of {budget} budget"
        )
        self.requested = requested
        self.live = live
        self.budget = budget


class MemoryGovernor:
    """LRU accounting of governed device buffers against one budget.

    `account(token, nbytes, evict=cb, owner=rel)` registers or resizes an
    entry; `touch` marks it recently used; `release` forgets it without
    calling its callback (the home cache already dropped it). Counters:
    `live_bytes` (current governed total), `peak_bytes`, `evictions`
    (entries removed to make room), `sheds` (account refusals)."""

    def __init__(self, budget_bytes: int | None = None):
        self.budget = budget_bytes
        self._entries: OrderedDict = OrderedDict()  # token -> [nbytes, evict_cb]
        self._fins: dict = {}  # token -> weakref.finalize on its owner
        self.live_bytes = 0
        self.peak_bytes = 0
        self.evictions = 0
        self.sheds = 0

    # ---- accounting ---------------------------------------------------
    def account(self, token, nbytes: int, *, evict=None, owner=None) -> None:
        """Register `token` at `nbytes` (or resize an existing entry),
        evicting cold entries as needed. Raises MemoryBudgetError — with
        the entry left exactly as it was — when no amount of eviction can
        make the growth fit."""
        nbytes = int(nbytes)
        entry = self._entries.get(token)
        delta = nbytes - (entry[0] if entry is not None else 0)
        if self.budget is not None and delta > 0:
            self._reserve(delta, protect=token)
        if entry is None:
            self._entries[token] = [nbytes, evict]
            if owner is not None and token not in self._fins:
                self._fins[token] = weakref.finalize(owner, self._owner_died, token)
        else:
            entry[0] = nbytes
            if evict is not None:
                entry[1] = evict
            self._entries.move_to_end(token)
        self.live_bytes += delta
        self.peak_bytes = max(self.peak_bytes, self.live_bytes)

    def touch(self, token) -> None:
        if token in self._entries:
            self._entries.move_to_end(token)

    def release(self, token) -> None:
        """Forget an entry WITHOUT its evict callback — the home cache has
        already dropped it (or is dropping it right now)."""
        entry = self._entries.pop(token, None)
        if entry is not None:
            self.live_bytes -= entry[0]
        fin = self._fins.pop(token, None)
        if fin is not None:
            fin.detach()

    def _owner_died(self, token) -> None:
        self._fins.pop(token, None)
        self.release(token)

    def _reserve(self, delta: int, *, protect=None) -> None:
        """Evict least-recently-touched entries until `delta` more bytes
        fit under the budget; raise (shed) when they cannot."""
        while self.live_bytes + delta > self.budget:
            victim = next((t for t in self._entries if t != protect), None)
            if victim is None:
                self.sheds += 1
                raise MemoryBudgetError(delta, self.live_bytes, self.budget)
            nbytes, cb = self._entries.pop(victim)
            self.live_bytes -= nbytes
            self.evictions += 1
            fin = self._fins.pop(victim, None)
            if fin is not None:
                fin.detach()
            if cb is not None:
                cb()

    # ---- configuration ------------------------------------------------
    def set_budget(self, budget_bytes: int | None) -> None:
        """Set (or clear) the budget. Shrinking below the current live
        total evicts coldest-first until the invariant holds again."""
        self.budget = budget_bytes
        if budget_bytes is not None and self.live_bytes > budget_bytes:
            self._reserve(0)

    def reset(self) -> None:
        """Drop all accounting (tests). Home caches are NOT touched —
        their entries simply stop being governed."""
        for fin in self._fins.values():
            fin.detach()
        self._fins.clear()
        self._entries.clear()
        self.live_bytes = 0


# the process-wide governor every compiled-path cache reports to
GOVERNOR = MemoryGovernor()


def set_budget(budget_bytes: int | None) -> None:
    GOVERNOR.set_budget(budget_bytes)


@contextlib.contextmanager
def budget(budget_bytes: int | None):
    """Scoped budget: `with membudget.budget(64 << 20): ...` — restores
    the previous budget (and its enforcement) on exit."""
    old = GOVERNOR.budget
    GOVERNOR.set_budget(budget_bytes)
    try:
        yield GOVERNOR
    finally:
        GOVERNOR.set_budget(old)


def _nbytes(x) -> int:
    """Total bytes of a nested structure of device/host arrays. Duck-typed
    on `.nbytes` so it never imports jax; containers recurse, scalars and
    None count zero."""
    if x is None:
        return 0
    if isinstance(x, dict):
        return sum(_nbytes(v) for v in x.values())
    if isinstance(x, (list, tuple)):
        return sum(_nbytes(v) for v in x)
    nb = getattr(x, "nbytes", None)
    return int(nb) if nb is not None else 0


def trie_nbytes(trie) -> int:
    """Device bytes held by one StaticTrie: every array leaf of its pytree
    flattening (level columns, sort order, group ids, hash tables, ...)."""
    children, _aux = trie.tree_flatten()
    return _nbytes(children)
