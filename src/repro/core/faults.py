"""Deterministic fault injection for the resilience layer.

Production brings failure modes no unit test triggers naturally: XLA
compile failures, device OOM (RESOURCE_EXHAUSTED), capacity-overflow
storms across batch lanes, dispatches that stall, and data mutated behind
the cache API's back. This module plants *injection points* at the exact
code sites where those faults strike — the compile site in
AdaptiveExecutor._fn, the dispatch site in AdaptiveExecutor.__call__ —
and arms them from tests through one context manager:

    with faults.inject("compile_fail", times=2) as f:
        engine.run()          # first two compiles raise InjectedCompileError
    assert f.fired == 2

Faults are consumed deterministically in arming order, `times` firings
each, and disarm when their context exits — no randomness, no globals
left behind. Kinds and their sites:

* "compile_fail"   (site "compile"):  raises InjectedCompileError before
  an executor build, exactly where a real XLA lowering failure surfaces.
* "device_oom"     (site "dispatch"): raises InjectedOOMError with
  RESOURCE_EXHAUSTED in the message, the device-allocator signature.
* "slow_dispatch"  (site "dispatch"): sleeps `delay_s` then proceeds —
  drives deadline handling without any real contention.
* "overflow_storm" (site "overflow"): raises capacity.CapacityQuotaError
  naming the next lane from `lanes` — a tenant repeatedly blowing its
  growth quota, without needing data that actually overflows.
* "mutation_skew"  (no site): swaps one host column for an equal-valued
  copy at arm time — the out-of-band mutation relcache detects.

`recoverable(exc)` is the degradation ladder's shared classifier: True
for injected faults, MemoryBudgetError (the governor shedding growth),
and real XLA RESOURCE_EXHAUSTED errors. `STATS` counts every firing by
kind for the chaos CI job; `python -m repro.core.faults` runs a canned
recovery scenario and prints the counters as a markdown summary.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time


class InjectedFault(RuntimeError):
    """Base of every injected error — always `recoverable`."""


class InjectedCompileError(InjectedFault):
    """Injected at the executor-build (compile) site."""


class InjectedOOMError(InjectedFault):
    """Injected at the dispatch site with the allocator's signature."""


@dataclasses.dataclass
class Fault:
    """One armed fault: `remaining` firings left, `fired` consumed."""

    kind: str
    site: str
    remaining: int
    fired: int = 0
    delay_s: float = 0.0
    lanes: tuple = ()
    need: int = 1 << 20


_SITE_OF = {
    "compile_fail": "compile",
    "device_oom": "dispatch",
    "slow_dispatch": "dispatch",
    "overflow_storm": "overflow",
    "mutation_skew": "mutation",
}

_ACTIVE: list[Fault] = []

# process-lifetime firing counters by kind (the chaos job's summary)
STATS = dict.fromkeys(_SITE_OF, 0)


def reset_stats() -> None:
    for k in STATS:
        STATS[k] = 0


@contextlib.contextmanager
def inject(
    kind: str,
    *,
    times: int = 1,
    delay_s: float = 0.01,
    lanes: tuple = (),
    need: int = 1 << 20,
    rel=None,
    var: str | None = None,
):
    """Arm one fault for the duration of the block; yields its Fault
    handle (inspect `fired` after). "mutation_skew" acts at arm time —
    it swaps a column of `rel` (var `var`, default the first schema var)
    for an equal-valued copy, the canonical out-of-band mutation."""
    if kind not in _SITE_OF:
        raise ValueError(f"unknown fault kind {kind!r}; one of {sorted(_SITE_OF)}")
    f = Fault(kind, _SITE_OF[kind], remaining=times, delay_s=delay_s,
              lanes=tuple(lanes), need=need)
    if kind == "mutation_skew":
        if rel is None:
            raise ValueError("mutation_skew needs rel=<Relation>")
        v = var if var is not None else next(iter(rel.schema))
        rel.columns[v] = rel.columns[v].copy()
        f.remaining, f.fired = 0, times
        STATS[kind] += times
        yield f
        return
    _ACTIVE.append(f)
    try:
        yield f
    finally:
        _ACTIVE.remove(f)


def fire(site: str, **ctx) -> None:
    """Called at an injection point. Consumes the first armed fault for
    `site` (if any) and acts it out; a no-op when nothing is armed — the
    production path pays one list check."""
    if not _ACTIVE:
        return
    for f in _ACTIVE:
        if f.site != site or f.remaining <= 0:
            continue
        f.remaining -= 1
        f.fired += 1
        STATS[f.kind] += 1
        if f.kind == "compile_fail":
            raise InjectedCompileError("injected compile failure (fault harness)")
        if f.kind == "device_oom":
            raise InjectedOOMError(
                "RESOURCE_EXHAUSTED: injected device OOM (fault harness)"
            )
        if f.kind == "slow_dispatch":
            time.sleep(f.delay_s)
            return
        if f.kind == "overflow_storm":
            from repro.core.capacity import CapacityQuotaError

            lane = None
            if ctx.get("batch"):
                seq = f.lanes or (0,)
                lane = int(seq[min(f.fired - 1, len(seq) - 1)])
            raise CapacityQuotaError(
                0, 0, int(f.need), int(ctx.get("max_capacity") or 0), lane=lane
            )
        return


def recoverable(exc: BaseException) -> bool:
    """Should the degradation ladder absorb this error? True for injected
    faults, governor sheds (MemoryBudgetError), and real device
    RESOURCE_EXHAUSTED / OOM errors. Everything else — including
    CapacityQuotaError, which has its own eviction protocol — propagates."""
    from repro.core.membudget import MemoryBudgetError

    if isinstance(exc, (InjectedFault, MemoryBudgetError)):
        return True
    if type(exc).__name__ != "XlaRuntimeError":
        return False
    msg = str(exc)
    return "RESOURCE_EXHAUSTED" in msg or "Out of memory" in msg


# ---------------------------------------------------------------------------
# Canned recovery scenario: the chaos CI job's summary (and smoke check)
# ---------------------------------------------------------------------------


def main() -> int:
    """Run one fault of each kind against a live serving engine and print
    a markdown recovery table. Exits nonzero if any admitted request
    crashed or answered wrong — the chaos job gates on this."""
    import numpy as np

    from repro.core import faults, membudget
    # under `python -m repro.core.faults` this file runs as __main__, a
    # module instance distinct from the repro.core.faults the engine's
    # injection points fire into — arm faults on the canonical one
    from repro.core.api import free_join
    from repro.relational.relation import Relation
    from repro.relational.schema import triangle_query
    from repro.serve import JoinServeEngine

    rng = np.random.default_rng(0)
    q = triangle_query()
    rels = {
        a.alias: Relation(a.alias, {v: rng.integers(0, 50, 2000) for v in a.vars})
        for a in q.atoms
    }
    consts = (3, 7)
    oracle = {c: free_join(q, rels, agg="count", filters={"x": c}) for c in consts}
    rows = []

    def run_engine(kind, **kw):
        eng = JoinServeEngine(slots=2)
        with faults.inject(kind, **kw) as f:
            reqs = [eng.submit(q, rels, {"x": c}) for c in consts]
            eng.run()
        ok = all(
            r.done and r.error is None and r.result == oracle[c]
            for r, c in zip(reqs, consts)
        )
        deg = sum(1 for r in reqs if r.degraded_to)
        rows.append((kind, f.fired, deg, ok))
        return ok

    ok = True
    ok &= run_engine("compile_fail", times=1)
    ok &= run_engine("device_oom", times=1)
    ok &= run_engine("slow_dispatch", times=1, delay_s=0.001)

    with membudget.budget(1 << 20) as gov:
        sheds0, evs0 = gov.sheds, gov.evictions
        for seed in range(4):
            r2 = np.random.default_rng(seed)
            rl = {
                a.alias: Relation(a.alias, {v: r2.integers(0, 40, 1500) for v in a.vars})
                for a in q.atoms
            }
            from repro.core.api import compiled_free_join

            got = compiled_free_join(q, rl, agg="count")
            want = free_join(q, rl, agg="count")
            ok &= got == want
            ok &= gov.live_bytes <= (1 << 20)
        rows.append(
            ("memory_budget", gov.evictions - evs0 + gov.sheds - sheds0, 0, ok)
        )

    print("### Fault-recovery counters\n")
    print("| fault | fired | degraded requests | recovered |")
    print("|---|---|---|---|")
    for kind, fired, deg, good in rows:
        print(f"| {kind} | {fired} | {deg} | {'yes' if good else 'NO'} |")
    print(f"\nlifetime firings: {dict(faults.STATS)}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
