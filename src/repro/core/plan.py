"""Free Join plans (Sec 3.2) and the plan pipeline of Sec 4.1:
binary plan -> binary2fj (Fig. 9) -> factor (Fig. 10).

A plan is a list of *nodes*; each node is a list of *subatoms* R(y).
The nodes must partition every atom's variables (Def 3.5), and a valid plan
(Def 3.7) requires (a) no two subatoms in one node share a relation and
(b) each node has a cover: a subatom containing all vars new to that node.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.relational.schema import Atom, Query


@dataclass(frozen=True)
class Subatom:
    alias: str
    vars: tuple[str, ...]

    def __str__(self):
        return f"{self.alias}({','.join(self.vars)})"


@dataclass
class FreeJoinPlan:
    query: Query
    nodes: list[list[Subatom]]

    def __str__(self):
        return "[" + ", ".join("[" + ", ".join(map(str, n)) + "]" for n in self.nodes) + "]"

    # ---- derived info -------------------------------------------------
    def vs(self, k: int) -> set[str]:
        return {v for sa in self.nodes[k] for v in sa.vars}

    def avs(self, k: int) -> set[str]:
        out: set[str] = set()
        for j in range(k):
            out |= self.vs(j)
        return out

    def covers(self, k: int) -> list[Subatom]:
        """Subatoms of node k containing all vars in vs(k) - avs(k)."""
        new = self.vs(k) - self.avs(k)
        return [sa for sa in self.nodes[k] if new <= set(sa.vars)]

    def partitions(self) -> dict[str, list[tuple[str, ...]]]:
        """alias -> list of var-groups in node order (the GHT schema,
        Sec 3.3 build phase, before the trailing [] / cover-last rule)."""
        out: dict[str, list[tuple[str, ...]]] = {a.alias: [] for a in self.query.atoms}
        for node in self.nodes:
            for sa in node:
                if sa.vars:
                    out[sa.alias].append(sa.vars)
        return out

    # ---- validity (Def 3.5 + Def 3.7) ---------------------------------
    def violations(self):
        """Yield every validity violation as (rule, locus, message) without
        raising: rule is a stable identifier ("plan-not-partitioning" |
        "node-repeats-relation" | "node-missing-cover"), locus the atom
        alias or node index it anchors to. `validate` raises on the first;
        the static verifier (repro.analysis.planlint) reports them all."""
        for atom in self.query.atoms:
            got = [
                v for node in self.nodes for sa in node if sa.alias == atom.alias for v in sa.vars
            ]
            if sorted(got) != sorted(atom.vars) or len(set(got)) != len(got):
                yield (
                    "plan-not-partitioning",
                    atom.alias,
                    f"plan does not partition atom {atom}: got {got} for vars {atom.vars}",
                )
        for k, node in enumerate(self.nodes):
            aliases = [sa.alias for sa in node]
            if len(set(aliases)) != len(aliases):
                yield ("node-repeats-relation", k, f"node {k} repeats a relation: {node}")
            if not self.covers(k):
                yield (
                    "node-missing-cover",
                    k,
                    f"node {k} has no cover: new vars {self.vs(k) - self.avs(k)}",
                )

    def validate(self) -> None:
        for _rule, _locus, message in self.violations():
            raise ValueError(message)

    def is_valid(self) -> bool:
        try:
            self.validate()
            return True
        except ValueError:
            return False


# ---------------------------------------------------------------------------
# Binary plans. A left-deep plan is a list of atoms [R1, ..., Rm].
# A bushy plan is a tree; we decompose it into left-deep stages (Sec 2.2).
# ---------------------------------------------------------------------------


@dataclass
class BinaryPlan:
    """A binary join plan tree. Leaves are atoms; internal nodes join two
    subplans. `decompose()` yields left-deep stages, materializing every
    right child that is itself a join (Sec 2.2)."""

    left: "BinaryPlan | Atom"
    right: "BinaryPlan | Atom"

    def decompose(self) -> list[tuple[str, list]]:
        """Returns stages [(stage_name, [leaf, ...])]. Leaves are Atoms or
        stage names (strings) referring to earlier materialized stages."""
        stages: list[tuple[str, list]] = []
        counter = [0]

        def go(node) -> list:
            if isinstance(node, Atom):
                return [node]
            chain = go(node.left)
            if isinstance(node.right, Atom):
                chain.append(node.right)
                return chain
            sub = go(node.right)
            counter[0] += 1
            name = f"__stage{counter[0]}"
            stages.append((name, sub))
            chain.append(name)
            return chain

        top = go(self)
        stages.append(("__root", top))
        return stages


def linear(atoms: list[Atom]) -> BinaryPlan:
    plan: BinaryPlan | Atom = atoms[0]
    for a in atoms[1:]:
        plan = BinaryPlan(plan, a)
    return plan  # type: ignore[return-value]


# ---------------------------------------------------------------------------
# Fig. 9: binary2fj — convert a left-deep plan to an equivalent Free Join plan
# ---------------------------------------------------------------------------


def binary2fj(left_deep: list[Atom], query: Query) -> FreeJoinPlan:
    r = left_deep[0]
    node: list[Subatom] = [Subatom(r.alias, tuple(r.vars))]
    fj: list[list[Subatom]] = []
    avs: set[str] = set(r.vars)
    for s in left_deep[1:]:
        probe_vars = tuple(v for v in s.vars if v in avs)
        node.append(Subatom(s.alias, probe_vars))
        fj.append(node)
        rest = tuple(v for v in s.vars if v not in avs)
        node = [Subatom(s.alias, rest)]
        avs |= set(s.vars)
    fj.append(node)
    plan = FreeJoinPlan(query, fj)
    plan.validate()
    return plan


# ---------------------------------------------------------------------------
# Fig. 10: factor — hoist fully-bound lookups into the previous node.
# Conservative: within a node, stop at the first lookup that cannot move
# (preserves the optimizer's lookup order). The node's cover never moves.
# ---------------------------------------------------------------------------


def factor(plan: FreeJoinPlan) -> FreeJoinPlan:
    nodes = [list(n) for n in plan.nodes]
    out = FreeJoinPlan(plan.query, nodes)
    for i in range(len(nodes) - 1, 0, -1):
        phi, prev = nodes[i], nodes[i - 1]
        avs = out.avs(i)
        for alpha in list(phi[1:]):  # lookups only; phi[0] is the cover
            if set(alpha.vars) <= avs and all(sa.alias != alpha.alias for sa in prev):
                phi.remove(alpha)
                prev.append(alpha)
            else:
                break  # conservative factoring
    out.nodes = [n for n in nodes if n]
    out.validate()
    return out


# ---------------------------------------------------------------------------
# Generic Join plan: a total variable order -> all-singleton-var nodes
# (Example 3.6, Eq. 3).
# ---------------------------------------------------------------------------


def gj_plan(query: Query, var_order: list[str]) -> FreeJoinPlan:
    if sorted(var_order) != sorted(query.variables):
        raise ValueError(f"var order {var_order} != query vars {query.variables}")
    nodes: list[list[Subatom]] = []
    for v in var_order:
        node = [Subatom(a.alias, (v,)) for a in query.atoms if v in a.vars]
        nodes.append(node)
    plan = FreeJoinPlan(query, nodes)
    plan.validate()
    return plan


def var_order_from_fj(plan: FreeJoinPlan) -> list[str]:
    """Free Join defines only a partial order on vars; extend to a total
    order by node sequence then subatom order (Sec 5.1 footnote)."""
    seen: dict[str, None] = {}
    for node in plan.nodes:
        for sa in node:
            for v in sa.vars:
                seen.setdefault(v)
    return list(seen)


# ---------------------------------------------------------------------------
# Stage derivation: a (possibly bushy) binary plan tree -> per-stage Free
# Join plans, root last (Sec 2.2 decomposition + binary2fj + factor per
# stage). Shared by the eager drivers, the compiled chain, and the
# optimizer's device cost model.
# ---------------------------------------------------------------------------


def decompose_tree(plan_tree) -> list:
    """Stages of a plan tree; a bare Atom (single-atom query) is its own
    root stage."""
    if isinstance(plan_tree, Atom):
        return [("__root", [plan_tree])]
    return plan_tree.decompose()


def stage_plans(query: Query, plan_tree, *, factorize: bool = True):
    """Per-stage Free Join plans of a (possibly bushy) binary plan tree:
    [(name, fj_plan)], root last. Each stage's plan is built over its own
    sub-query (fj.query), whose head is the stage's output schema; later
    stages reference earlier ones by name as ordinary atoms."""
    stage_schemas: dict[str, tuple[str, ...]] = {}
    out = []
    for name, leaves in decompose_tree(plan_tree):
        atoms = [
            leaf if isinstance(leaf, Atom) else Atom(leaf, stage_schemas[leaf])
            for leaf in leaves
        ]
        sub_q = Query(atoms)
        fj = binary2fj(atoms, sub_q)
        if factorize:
            fj = factor(fj)
        stage_schemas[name] = sub_q.head
        out.append((name, fj))
    return out
