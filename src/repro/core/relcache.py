"""Weakref-keyed cache registry for per-relation device state.

The compiled path keeps three kinds of state alive across calls so
steady-state serving pays probe cost only: device uploads of base columns,
built StaticTries, and per-column planning statistics. All of it is
per-Relation-object, and all of it must die with the relation — caching by
`id(rel)` is unsound (CPython reuses addresses after GC, so a dead
relation's entry could be served to an unrelated new object), and caching
by content is exactly the O(N) work the cache exists to avoid.

Two primitives, both identity-keyed *through weak references* so an entry
can never outlive (or be confused with) its relation:

* `RelationRegistry` — relation -> named namespace dicts. Backed by a
  WeakKeyDictionary: the interpreter drops the whole entry the moment the
  relation is collected. Identity comes from the live object, never from a
  reusable address.
* `KeyedCache` — bounded mapping whose keys may span *several* relations
  (a partition of a whole query, a compiled runner over a relation dict).
  Relation identity goes into the key as `id(rel)`, but every entry
  registers a `weakref.finalize` on each relation that evicts the entry on
  death — the id can only be reused after the finalizer has already
  removed the stale entry, closing the reuse race by construction.

Values held here are strong references (device arrays, compiled
executors): that is the point — they are the cache. Lifetime is bounded by
the relations themselves plus the LRU bound on KeyedCache.

Since PR 9 the registry also carries each relation's MUTATION STATE — the
delta-build contract that replaced rebuild-on-any-change:

* `append(rel, delta_cols)` extends the host columns AND primes every
  identity-keyed memo (device upload, radix key width, distinct count)
  with an incrementally-computed value, so the next planning/build pass
  pays O(delta), not O(N). The delta itself lands in a bounded version log
  that compiled.TrieCache replays: a cached trie catches up by sorting
  only the delta (segmented radix kernel) and merging sorted runs — no
  full re-sort.
* `delete(rel, rows)` writes tombstones: rows keep their physical slots
  with multiplicity 0 (the weighted-trie mult-fold makes them contribute
  nothing). When live/total drops below the state's `compact_ratio`,
  `compact()` physically drops dead rows — replacing the host column
  objects, so every identity-keyed consumer sees the full rebuild a
  compaction is.
* Each mutation bumps the relation's `version` (a per-relation clock);
  consumers that cache derived device state record the version they
  materialized at and use `deltas_since(v)` to replay exactly the missing
  suffix — or rebuild, when the suffix was pruned or a compaction reset
  the clock.
"""
from __future__ import annotations

import warnings
import weakref
from collections import OrderedDict

import numpy as np


class RelationRegistry:
    """Per-relation namespaces: `namespace(rel, "tries")` returns a dict
    private to (rel, "tries") that dies with `rel`."""

    def __init__(self):
        self._spaces: weakref.WeakKeyDictionary = weakref.WeakKeyDictionary()

    def namespace(self, rel, name: str) -> dict:
        spaces = self._spaces.get(rel)
        if spaces is None:
            spaces = {}
            self._spaces[rel] = spaces
        return spaces.setdefault(name, {})

    def clear(self) -> None:
        self._spaces.clear()


def memo(registry: "RelationRegistry", rel, space: str, key, obj, compute):
    """The registry's one validation idiom, shared by every per-relation
    memo (device uploads, key widths, distinct counts): cache `compute()`
    under (rel, space, key), revalidated by `obj` identity — a replaced
    column object recomputes, an identical one returns the cached value.
    In-place mutation of `obj` is undetectable by design; replace the
    object instead."""
    ns = registry.namespace(rel, space)
    hit = ns.get(key)
    if hit is None or hit[0] is not obj:
        ns[key] = (obj, compute())
    return ns[key][1]


class KeyedCache:
    """Bounded LRU cache whose entries are pinned to relation lifetimes.

    `put(key, value, rels)` stores value under `key` (which should embed
    `id(r)` for each r in rels to make identity part of the key) and
    arranges for the entry to be evicted when any of `rels` is collected.

    `hits`/`misses` count every get() outcome — the observable contract
    serving tests lock ("N queries, one compile" shows up as one miss and
    N-1 hits). `scoped(tag)` returns a view whose keys live under `tag` in
    the same bounded store, so independent keying disciplines (verbatim
    runner keys vs canonicalized template keys) can share one cache without
    ever colliding.

    `on_evict`, if set, is called as `on_evict(key, value)` on EVERY path
    an entry leaves the cache — put-replacement, LRU overflow, finalizer
    eviction, explicit _evict, clear — so external accounting (the device-
    memory governor) can never go stale against the cache's contents.
    """

    def __init__(self, max_entries: int = 64):
        self.max_entries = max_entries
        self._data: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.on_evict = None  # callable (key, value), see class docstring

    def get(self, key):
        hit = self._data.get(key)
        if hit is None:
            self.misses += 1
            return None
        self.hits += 1
        self._data.move_to_end(key)
        return hit[0]

    def scoped(self, tag: str) -> "ScopedCache":
        return ScopedCache(self, tag)

    def put(self, key, value, rels=()) -> None:
        old = self._data.pop(key, None)
        if old is not None:
            for fin in old[1]:
                fin.detach()
            if self.on_evict is not None and old[0] is not value:
                self.on_evict(key, old[0])
        fins = tuple(weakref.finalize(r, self._evict, key) for r in rels)
        self._data[key] = (value, fins)
        while len(self._data) > self.max_entries:
            k, (v, evicted_fins) = self._data.popitem(last=False)
            for fin in evicted_fins:
                fin.detach()
            if self.on_evict is not None:
                self.on_evict(k, v)

    def _evict(self, key) -> None:
        entry = self._data.pop(key, None)
        if entry is not None:
            for fin in entry[1]:
                fin.detach()
            if self.on_evict is not None:
                self.on_evict(key, entry[0])

    def __len__(self) -> int:
        return len(self._data)

    def clear(self) -> None:
        for k, (v, fins) in self._data.items():
            for fin in fins:
                fin.detach()
            if self.on_evict is not None:
                self.on_evict(k, v)
        self._data.clear()


class ScopedCache:
    """Namespace view over a KeyedCache: every key is stored as
    (tag, key), sharing the parent's LRU bound, finalizer discipline, and
    hit/miss counters. Used to give template-canonicalized runner keys
    their own namespace inside the runner cache."""

    def __init__(self, parent: KeyedCache, tag: str):
        self._parent = parent
        self._tag = tag

    def get(self, key):
        return self._parent.get((self._tag, key))

    def put(self, key, value, rels=()) -> None:
        self._parent.put((self._tag, key), value, rels)

    @property
    def hits(self) -> int:
        return self._parent.hits

    @property
    def misses(self) -> int:
        return self._parent.misses


class CardFeedback:
    """Measured-cardinality store: the optimizer's feedback loop.

    The compiled executor reports, for every executed node, the *exact*
    number of frontier lanes its expansion produced — which, for a node
    whose cover binds only fresh variables, is precisely the size of the
    join of the per-relation consumed prefixes (distinct-combination
    semantics, the same currency optimizer.prefix_card estimates). The
    adaptive runner records those measurements here after each successful
    unfiltered (or mask-mode batched) run; plan enumeration and capacity
    planning then consult the store, so a warm template re-optimizes and
    re-sizes against measured, not estimated, cardinalities.

    Keys are multisets of (relation identity, consumed-var set) pairs —
    one per atom of the measured sub-join — so a measurement taken under
    one plan transfers to any other plan (or any other query) joining the
    same prefixes of the same relation objects. Entries ride a KeyedCache,
    so they are LRU-bounded and die with their relations (weakref
    finalizers); id() reuse can never resurrect a stale measurement.

    `version` increments only when a recording *changes* the store
    materially (a new key, or a value drifting past `rtol`). Plan choice
    caches key on it: a steady-state stream of identical runs re-records
    identical measurements, never bumps the version, and therefore never
    re-enumerates."""

    def __init__(self, max_entries: int = 2048, rtol: float = 1.25):
        self._cache = KeyedCache(max_entries=max_entries)
        self.rtol = rtol
        self.version = 0
        self.records = 0  # record() calls that changed the store

    @staticmethod
    def key(specs) -> tuple:
        """specs: iterable of (rel, vars) pairs. The multiset is order-
        insensitive but duplicate-preserving (self-joins keep both legs)."""
        return tuple(sorted((id(r), tuple(sorted(vs))) for r, vs in specs))

    def record(self, specs, card: float) -> None:
        specs = list(specs)
        key = self.key(specs)
        card = float(max(1.0, card))
        old = self._cache.get(key)
        if old is not None and max(old, card) <= self.rtol * min(old, card):
            return  # within tolerance: keep the store (and the version) still
        self._cache.put(key, card, [r for r, _ in specs])
        self.records += 1
        self.version += 1

    def lookup(self, specs) -> float | None:
        return self._cache.get(self.key(specs))

    def __len__(self) -> int:
        return len(self._cache)

    def clear(self) -> None:
        self._cache.clear()
        self.version += 1


# the process-wide registry every compiled-path cache hangs off
REGISTRY = RelationRegistry()

# the process-wide measured-cardinality store (see CardFeedback)
FEEDBACK = CardFeedback()


# ---------------------------------------------------------------------------
# Mutation state: the delta-build contract (see module docstring)
# ---------------------------------------------------------------------------


class MutationState:
    """Versioned delta log + liveness mask for one mutating relation.

    `version` is the relation's mutation clock: every append/delete/compact
    bumps it. Consumers that cache derived device state (TrieCache entries,
    standing-query stage fingerprints) record the version they materialized
    at; `deltas_since(v)` returns the log suffix they must replay — or None
    when that suffix was pruned or a compaction reset the clock, which
    means "rebuild from scratch".

    Tombstone semantics: `delete` never moves a row. The host-side `mult`
    mask zeroes the row (device tries scatter the same rows into their mult
    column), and the weighted-trie mult-fold makes dead rows contribute
    nothing to counts or materialized outputs. Physical rows shrink only at
    `compact()`, which runs automatically once live/total < `compact_ratio`.
    """

    def __init__(self, rel, *, compact_ratio: float = 0.5, max_log: int = 64):
        self.version = 0
        self.base_version = 0  # the log holds versions (base_version, version]
        self.compact_ratio = compact_ratio
        self.max_log = max_log
        self.total = rel.num_rows  # physical host rows (live + tombstoned)
        self.live = rel.num_rows
        self.mult = None  # (total,) int32 host liveness mask; None = all live
        self.log: list[tuple] = []  # (version, "append"|"delete", payload)
        self.cols = dict(rel.columns)  # current column identities (authority)
        self.cols0 = dict(rel.columns)  # pre-mutation identities (adoption)
        self.uniques: dict[str, np.ndarray] = {}  # var -> sorted distincts
        self._live_rel: tuple | None = None  # (version, Relation) snapshot
        self.appends = 0
        self.deletes = 0
        self.compactions = 0
        # device uploads of the version-0 columns, captured at state birth:
        # the handle TrieCache uses to recognize a trie built BEFORE the
        # first mutation and adopt it as the version-0 merge base (the
        # "warm build, then stream" path pays no full rebuild at all)
        dev_ns = REGISTRY.namespace(rel, "dev_cols")
        self.dev0 = {}
        for v in rel.schema:
            hit = dev_ns.get(v)
            if hit is not None and hit[0] is rel.columns[v]:
                self.dev0[v] = hit[1]

    def validate(self, rel) -> bool:
        """True while the relation's columns are the ones this state last
        produced. A column replaced behind the API's back (out-of-band
        mutation) fails this, and the state abdicates — identity
        revalidation of the plain memos regains authority."""
        return all(self.cols.get(v) is rel.columns[v] for v in rel.schema)

    def deltas_since(self, version: int) -> list[tuple] | None:
        if version < self.base_version:
            return None
        return [e for e in self.log if e[0] > version]

    def distinct(self, var: str) -> float | None:
        """Incrementally-maintained distinct count (an upper bound after
        deletes — tombstoned values are not retired until compaction)."""
        u = self.uniques.get(var)
        return None if u is None else float(max(1, len(u)))

    def _prune(self) -> None:
        while len(self.log) > self.max_log:
            self.base_version = self.log.pop(0)[0]


# Out-of-band mutation observability: a column replaced behind the delta
# API is handled correctly (the stale state abdicates and identity-keyed
# caches fully rebuild) but that fallback used to be silent — a workload
# quietly paying rebuild-per-query looked identical to a healthy one.
# Every detection now bumps a counter and the first one warns.
_OOB = {"swaps": 0, "warned": False}


def oob_swaps() -> int:
    """Process-lifetime count of out-of-band column swaps detected on
    mutating relations (each one dropped a delta log and forced cached
    tries to fully rebuild)."""
    return _OOB["swaps"]


def reset_oob_warning() -> None:
    """Re-arm the one-shot out-of-band-swap warning (tests)."""
    _OOB["warned"] = False


def _note_oob(rel) -> None:
    _OOB["swaps"] += 1
    if not _OOB["warned"]:
        _OOB["warned"] = True
        warnings.warn(
            f"out-of-band column swap detected on mutating relation "
            f"{rel.name!r}: its delta log was dropped and cached tries will "
            "fully rebuild. Mutate through relcache.append/delete/compact to "
            "keep delta merges. (Warned once per process; "
            "relcache.oob_swaps() counts every detection.)",
            RuntimeWarning,
            stacklevel=4,
        )


def mutation_state(rel) -> MutationState | None:
    """The relation's mutation state, or None if it was never mutated
    through this API (or was mutated out-of-band, which drops the stale
    state so the identity-keyed caches see a plain full rebuild)."""
    ns = REGISTRY.namespace(rel, "mutation")
    st = ns.get("state")
    if st is not None and not st.validate(rel):
        del ns["state"]
        _note_oob(rel)
        return None
    return st


def _state_of(rel) -> MutationState:
    ns = REGISTRY.namespace(rel, "mutation")
    st = ns.get("state")
    if st is None or not st.validate(rel):
        if st is not None:
            _note_oob(rel)
        st = MutationState(rel)
        ns["state"] = st
    return st


def append(rel, delta_cols: dict) -> MutationState:
    """Append rows to `rel` through the delta contract.

    Host columns are extended in place (new array objects), and every
    per-column memo is *primed* with an incrementally-computed value so the
    next build/planning pass pays O(delta):

    * "dev_cols": the cached device upload is extended by a device-side
      concat of the delta — no O(N) host-to-device re-transfer;
    * "key_bits": the radix sort width grows by a max over the delta;
    * "distinct": one np.union1d over the delta against the maintained
      sorted-distinct set (the optimizer's delta-aware size estimates).

    The delta lands in the version log; compiled.TrieCache replays it by
    sorting only the delta and merging sorted runs into the cached level
    buffers (zero full re-sorts)."""
    import jax.numpy as jnp  # deferred: relcache stays importable sans jax

    st = _state_of(rel)
    missing = set(rel.schema) - set(delta_cols)
    if missing:
        raise ValueError(f"append missing columns: {sorted(missing)}")
    arrs = {v: np.asarray(delta_cols[v]) for v in rel.schema}
    lens = {len(a) for a in arrs.values()}
    if len(lens) > 1:
        raise ValueError(f"ragged delta columns: {lens}")
    m = lens.pop() if lens else 0
    if m == 0:
        return st
    dev_ns = REGISTRY.namespace(rel, "dev_cols")
    bit_ns = REGISTRY.namespace(rel, "key_bits")
    dis_ns = REGISTRY.namespace(rel, "distinct")
    log_cols = {}
    for v in rel.schema:
        old = rel.columns[v]
        delta = arrs[v].astype(old.dtype, copy=False)
        new = np.concatenate([old, delta])
        hit = dev_ns.get(v)
        if hit is not None and hit[0] is old:
            dev_ns[v] = (new, jnp.concatenate([hit[1], jnp.asarray(delta, jnp.int32)]))
        hit = bit_ns.get(v)
        if hit is not None and hit[0] is old:
            if hit[1] is None or int(delta.min()) < 0:
                width = None
            else:
                width = max(hit[1], 1, int(delta.max()).bit_length())
            bit_ns[v] = (new, width)
        uniq = st.uniques.get(v)
        if uniq is None:  # first append pays one full unique; then O(delta)
            uniq = np.unique(old)
        uniq = np.union1d(uniq, delta)
        st.uniques[v] = uniq
        dis_ns[v] = (new, float(max(1, len(uniq))))
        rel.columns[v] = new
        log_cols[v] = np.ascontiguousarray(delta)
    rel.num_rows += m
    if st.mult is not None:
        st.mult = np.concatenate([st.mult, np.ones(m, np.int32)])
    st.total += m
    st.live += m
    st.version += 1
    st.appends += 1
    st.log.append((st.version, "append", log_cols))
    st._prune()
    st.cols = dict(rel.columns)
    st._live_rel = None
    return st


def delete(rel, rows) -> MutationState:
    """Tombstone rows of `rel` by physical index (row i is column[i]).
    Dead rows keep their slots with multiplicity 0 until live/total drops
    below the state's compact_ratio, at which point compact() runs — the
    "real rebuild" threshold of the delta contract."""
    st = _state_of(rel)
    rows = np.unique(np.asarray(rows, np.int64))
    if rows.size == 0:
        return st
    if int(rows[0]) < 0 or int(rows[-1]) >= st.total:
        raise IndexError(f"delete rows out of range [0, {st.total})")
    if st.mult is None:
        st.mult = np.ones(st.total, np.int32)
    newly = int(np.count_nonzero(st.mult[rows]))
    st.mult[rows] = 0
    st.live -= newly
    st.version += 1
    st.deletes += 1
    st.log.append((st.version, "delete", rows.astype(np.int32)))
    st._prune()
    st._live_rel = None
    if st.total and st.live / st.total < st.compact_ratio:
        compact(rel)
    return st


def compact(rel) -> int:
    """Physically drop tombstoned rows. Host columns are REPLACED (new
    array objects), so every identity-keyed memo and cached trie sees the
    full rebuild a compaction is; the version log is cleared and
    base_version advanced so no cached consumer can "catch up" across it.
    Returns the number of rows dropped."""
    st = _state_of(rel)
    dropped = 0
    if st.mult is not None:
        mask = st.mult != 0
        dropped = int(st.total - np.count_nonzero(mask))
        if dropped:
            for v in rel.schema:
                rel.columns[v] = rel.columns[v][mask]
        rel.num_rows = int(np.count_nonzero(mask))
    st.mult = None
    st.total = st.live = rel.num_rows
    st.version += 1
    st.compactions += 1
    st.log.clear()
    st.base_version = st.version
    st.cols = dict(rel.columns)
    st.cols0 = dict(rel.columns)
    st.uniques.clear()  # deletes may have shrunk domains: recompute lazily
    st._live_rel = None
    return dropped


def live_relation(rel):
    """Live-rows host snapshot (tombstones dropped): the eager-path and
    oracle view of a mutating relation. Cached per version, so repeated
    calls at the same version return the identical object and downstream
    identity-keyed memos (device uploads) stay warm."""
    st = mutation_state(rel)
    if st is None or st.mult is None or st.live == st.total:
        return rel
    if st._live_rel is not None and st._live_rel[0] == st.version:
        return st._live_rel[1]
    from repro.relational.relation import Relation  # deferred: no cycle

    mask = st.mult != 0
    snap = Relation(rel.name, {v: rel.columns[v][mask] for v in rel.schema})
    st._live_rel = (st.version, snap)
    return snap


def live_size(rel) -> int:
    """Live row count: num_rows minus tombstones (the size the optimizer's
    delta-aware estimates should plan for)."""
    st = mutation_state(rel)
    return rel.num_rows if st is None else st.live
