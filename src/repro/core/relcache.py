"""Weakref-keyed cache registry for per-relation device state.

The compiled path keeps three kinds of state alive across calls so
steady-state serving pays probe cost only: device uploads of base columns,
built StaticTries, and per-column planning statistics. All of it is
per-Relation-object, and all of it must die with the relation — caching by
`id(rel)` is unsound (CPython reuses addresses after GC, so a dead
relation's entry could be served to an unrelated new object), and caching
by content is exactly the O(N) work the cache exists to avoid.

Two primitives, both identity-keyed *through weak references* so an entry
can never outlive (or be confused with) its relation:

* `RelationRegistry` — relation -> named namespace dicts. Backed by a
  WeakKeyDictionary: the interpreter drops the whole entry the moment the
  relation is collected. Identity comes from the live object, never from a
  reusable address.
* `KeyedCache` — bounded mapping whose keys may span *several* relations
  (a partition of a whole query, a compiled runner over a relation dict).
  Relation identity goes into the key as `id(rel)`, but every entry
  registers a `weakref.finalize` on each relation that evicts the entry on
  death — the id can only be reused after the finalizer has already
  removed the stale entry, closing the reuse race by construction.

Values held here are strong references (device arrays, compiled
executors): that is the point — they are the cache. Lifetime is bounded by
the relations themselves plus the LRU bound on KeyedCache.
"""
from __future__ import annotations

import weakref
from collections import OrderedDict


class RelationRegistry:
    """Per-relation namespaces: `namespace(rel, "tries")` returns a dict
    private to (rel, "tries") that dies with `rel`."""

    def __init__(self):
        self._spaces: weakref.WeakKeyDictionary = weakref.WeakKeyDictionary()

    def namespace(self, rel, name: str) -> dict:
        spaces = self._spaces.get(rel)
        if spaces is None:
            spaces = {}
            self._spaces[rel] = spaces
        return spaces.setdefault(name, {})

    def clear(self) -> None:
        self._spaces.clear()


def memo(registry: "RelationRegistry", rel, space: str, key, obj, compute):
    """The registry's one validation idiom, shared by every per-relation
    memo (device uploads, key widths, distinct counts): cache `compute()`
    under (rel, space, key), revalidated by `obj` identity — a replaced
    column object recomputes, an identical one returns the cached value.
    In-place mutation of `obj` is undetectable by design; replace the
    object instead."""
    ns = registry.namespace(rel, space)
    hit = ns.get(key)
    if hit is None or hit[0] is not obj:
        ns[key] = (obj, compute())
    return ns[key][1]


class KeyedCache:
    """Bounded LRU cache whose entries are pinned to relation lifetimes.

    `put(key, value, rels)` stores value under `key` (which should embed
    `id(r)` for each r in rels to make identity part of the key) and
    arranges for the entry to be evicted when any of `rels` is collected.

    `hits`/`misses` count every get() outcome — the observable contract
    serving tests lock ("N queries, one compile" shows up as one miss and
    N-1 hits). `scoped(tag)` returns a view whose keys live under `tag` in
    the same bounded store, so independent keying disciplines (verbatim
    runner keys vs canonicalized template keys) can share one cache without
    ever colliding.
    """

    def __init__(self, max_entries: int = 64):
        self.max_entries = max_entries
        self._data: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0

    def get(self, key):
        hit = self._data.get(key)
        if hit is None:
            self.misses += 1
            return None
        self.hits += 1
        self._data.move_to_end(key)
        return hit[0]

    def scoped(self, tag: str) -> "ScopedCache":
        return ScopedCache(self, tag)

    def put(self, key, value, rels=()) -> None:
        old = self._data.pop(key, None)
        if old is not None:
            for fin in old[1]:
                fin.detach()
        fins = tuple(weakref.finalize(r, self._evict, key) for r in rels)
        self._data[key] = (value, fins)
        while len(self._data) > self.max_entries:
            _k, (_v, evicted_fins) = self._data.popitem(last=False)
            for fin in evicted_fins:
                fin.detach()

    def _evict(self, key) -> None:
        entry = self._data.pop(key, None)
        if entry is not None:
            for fin in entry[1]:
                fin.detach()

    def __len__(self) -> int:
        return len(self._data)

    def clear(self) -> None:
        for _k, (_v, fins) in self._data.items():
            for fin in fins:
                fin.detach()
        self._data.clear()


class ScopedCache:
    """Namespace view over a KeyedCache: every key is stored as
    (tag, key), sharing the parent's LRU bound, finalizer discipline, and
    hit/miss counters. Used to give template-canonicalized runner keys
    their own namespace inside the runner cache."""

    def __init__(self, parent: KeyedCache, tag: str):
        self._parent = parent
        self._tag = tag

    def get(self, key):
        return self._parent.get((self._tag, key))

    def put(self, key, value, rels=()) -> None:
        self._parent.put((self._tag, key), value, rels)

    @property
    def hits(self) -> int:
        return self._parent.hits

    @property
    def misses(self) -> int:
        return self._parent.misses


class CardFeedback:
    """Measured-cardinality store: the optimizer's feedback loop.

    The compiled executor reports, for every executed node, the *exact*
    number of frontier lanes its expansion produced — which, for a node
    whose cover binds only fresh variables, is precisely the size of the
    join of the per-relation consumed prefixes (distinct-combination
    semantics, the same currency optimizer.prefix_card estimates). The
    adaptive runner records those measurements here after each successful
    unfiltered (or mask-mode batched) run; plan enumeration and capacity
    planning then consult the store, so a warm template re-optimizes and
    re-sizes against measured, not estimated, cardinalities.

    Keys are multisets of (relation identity, consumed-var set) pairs —
    one per atom of the measured sub-join — so a measurement taken under
    one plan transfers to any other plan (or any other query) joining the
    same prefixes of the same relation objects. Entries ride a KeyedCache,
    so they are LRU-bounded and die with their relations (weakref
    finalizers); id() reuse can never resurrect a stale measurement.

    `version` increments only when a recording *changes* the store
    materially (a new key, or a value drifting past `rtol`). Plan choice
    caches key on it: a steady-state stream of identical runs re-records
    identical measurements, never bumps the version, and therefore never
    re-enumerates."""

    def __init__(self, max_entries: int = 2048, rtol: float = 1.25):
        self._cache = KeyedCache(max_entries=max_entries)
        self.rtol = rtol
        self.version = 0
        self.records = 0  # record() calls that changed the store

    @staticmethod
    def key(specs) -> tuple:
        """specs: iterable of (rel, vars) pairs. The multiset is order-
        insensitive but duplicate-preserving (self-joins keep both legs)."""
        return tuple(sorted((id(r), tuple(sorted(vs))) for r, vs in specs))

    def record(self, specs, card: float) -> None:
        specs = list(specs)
        key = self.key(specs)
        card = float(max(1.0, card))
        old = self._cache.get(key)
        if old is not None and max(old, card) <= self.rtol * min(old, card):
            return  # within tolerance: keep the store (and the version) still
        self._cache.put(key, card, [r for r, _ in specs])
        self.records += 1
        self.version += 1

    def lookup(self, specs) -> float | None:
        return self._cache.get(self.key(specs))

    def __len__(self) -> int:
        return len(self._cache)

    def clear(self) -> None:
        self._cache.clear()
        self.version += 1


# the process-wide registry every compiled-path cache hangs off
REGISTRY = RelationRegistry()

# the process-wide measured-cardinality store (see CardFeedback)
FEEDBACK = CardFeedback()
