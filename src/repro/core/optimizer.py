"""A small cost-based optimizer producing binary join plans, plus the
per-prefix cardinality estimates that drive the compiled path's capacity
planner (core/capacity.py).

The paper uses DuckDB's optimizer; DuckDB is not available in this
container, so we implement the classic textbook estimator: greedy left-deep
join ordering driven by cardinality estimates
|L join R| = |L|*|R| / prod_{v shared} max(d_L(v), d_R(v)).

`bad=True` reproduces the paper's Sec 5.4 hijack — every cardinality
estimate is pinned to 1 — under which the greedy search degenerates to
input order and we emit a *bushy* balanced tree (the paper observes DuckDB
"routinely outputs bushy plans that materialize large results" in this
regime).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from repro.core.plan import BinaryPlan, FreeJoinPlan, linear
from repro.relational.relation import Relation
from repro.relational.schema import Atom, Query


class Est:
    """A cardinality estimate: expected row count plus per-variable distinct
    counts (the state threaded through the System-R style estimator)."""

    def __init__(self, card: float, distinct: dict[str, float], atoms: list[Atom]):
        self.card = card
        self.distinct = distinct
        self.atoms = atoms


class Stats:
    """Per-column statistics shared across one query's whole planning pass
    (optimize -> plan_capacities -> estimate_prefixes): each referenced
    column is np.unique'd exactly once and the result cached. Holds a live
    reference to the driver's relation dict, so stage relations materialized
    mid-query are visible without rebuilding the cache.

    cached=True additionally persists each distinct count in the process-
    wide weakref registry (core/relcache.py), keyed by relation + column
    object identity — the compiled driver's steady-state surface, where a
    repeated query over the same relations pays zero np.unique calls. The
    default stays per-instance so eager-path callers keep the one-pass
    contract without touching global state."""

    def __init__(self, relations: dict[str, Relation], *, cached: bool = False):
        self.relations = relations
        self._distinct: dict[tuple[str, str], float] = {}
        self._cached = cached

    def size(self, alias: str) -> int:
        return self.relations[alias].num_rows

    def distinct(self, alias: str, var: str) -> float:
        key = (alias, var)
        if key not in self._distinct:
            rel = self.relations[alias]
            col = rel.columns[var]

            def compute():
                return float(max(1, len(np.unique(col))))

            if self._cached:
                from repro.core import relcache

                self._distinct[key] = relcache.memo(
                    relcache.REGISTRY, rel, "distinct", var, col, compute
                )
            else:
                self._distinct[key] = compute()
        return self._distinct[key]


class StageStats:
    """Statistics view that also answers for *planned* stage outputs —
    relations that never exist on the host, because the chained compiled
    path materializes them only as device buffers. A stage's size and
    per-var distinct counts come from the optimizer's Est of its sub-query
    (register() after planning the stage, before any downstream stage reads
    it); every other alias falls through to the base Stats cache, so the
    whole chain still costs one np.unique per referenced base column."""

    def __init__(self, base: Stats):
        self.base = base
        self._stage: dict[str, Est] = {}

    def register(self, alias: str, est: Est) -> None:
        self._stage[alias] = est

    def size(self, alias: str) -> int:
        if alias in self._stage:
            return int(max(1.0, self._stage[alias].card))
        return self.base.size(alias)

    def distinct(self, alias: str, var: str) -> float:
        if alias in self._stage:
            e = self._stage[alias]
            return float(min(max(1.0, e.distinct.get(var, e.card)), max(1.0, e.card)))
        return self.base.distinct(alias, var)


class FilteredStats:
    """Statistics view for a query carrying equality selections (the serving
    path's plan *templates*: `v = ?` with the constant lifted out of the
    plan). A filtered variable contributes exactly one distinct value, and
    every atom containing it shrinks by that column's selectivity
    (size / distinct), so capacity planning sizes frontier buffers for the
    *selected* slice instead of the whole relation — the difference between
    a batched probe lane costing O(rows-matching-constant) and
    O(all-rows). Deliberately value-agnostic: the estimates depend only on
    WHICH vars are filtered, never on the constants, so every query of a
    template shares one plan and one executor.

    `filtered` maps alias -> the set of that atom's filtered vars. Plan
    choice (optimize) should keep using the unfiltered base stats — the
    binary plan must be template-stable too; this view feeds capacity
    planning, where an under-estimate is recovered by the adaptive runner's
    exact-need growth."""

    def __init__(self, base, filtered: dict[str, frozenset[str]]):
        self.base = base
        self.filtered = {a: frozenset(vs) for a, vs in filtered.items() if vs}

    def size(self, alias: str) -> int:
        s = float(max(1, self.base.size(alias)))
        for v in self.filtered.get(alias, ()):
            s /= max(1.0, self.base.distinct(alias, v))
        return int(max(1.0, math.ceil(s)))

    def distinct(self, alias: str, var: str) -> float:
        if var in self.filtered.get(alias, frozenset()):
            return 1.0
        return float(min(self.base.distinct(alias, var), max(1, self.size(alias))))


def stage_est(atoms: list[Atom], stats) -> Est:
    """Estimated output of joining `atoms` (a stage sub-query): fold the
    binary estimator left to right. `stats` may be a StageStats so earlier
    stages' estimates flow into later stages'."""
    cur = base_est(atoms[0], stats)
    for a in atoms[1:]:
        cur = join_est(cur, base_est(a, stats))
    return cur


def base_est(atom: Atom, stats: Stats, bad: bool = False) -> Est:
    if bad:
        return Est(1.0, {v: 1.0 for v in atom.vars}, [atom])
    d = {v: stats.distinct(atom.alias, v) for v in atom.vars}
    return Est(float(max(1, stats.size(atom.alias))), d, [atom])


def join_est(a: Est, b: Est) -> Est:
    shared = set(a.distinct) & set(b.distinct)
    denom = 1.0
    for v in shared:
        denom *= max(a.distinct[v], b.distinct[v])
    card = max(1.0, a.card * b.card / max(1.0, denom))
    d = dict(a.distinct)
    for v, dv in b.distinct.items():
        d[v] = min(d.get(v, float("inf")), dv, card)
    d = {v: min(dv, card) for v, dv in d.items()}
    return Est(card, d, a.atoms + b.atoms)


def optimize(
    query: Query,
    relations: dict[str, Relation],
    bad: bool = False,
    *,
    stats: Stats | None = None,
) -> BinaryPlan | Atom:
    if stats is None:
        stats = Stats(relations)
    ests = [base_est(a, stats, bad) for a in query.atoms]
    if bad:
        # balanced bushy over input order (all estimates tie at 1)
        nodes: list = list(query.atoms)
        while len(nodes) > 1:
            nxt = []
            for i in range(0, len(nodes) - 1, 2):
                nxt.append(BinaryPlan(nodes[i], nodes[i + 1]))
            if len(nodes) % 2:
                nxt.append(nodes[-1])
            nodes = nxt
        return nodes[0]  # single-atom queries get the atom, not a self-join
    # greedy left-deep: best starting pair, then best extension
    best_pair, best_card = None, float("inf")
    for i in range(len(ests)):
        for j in range(len(ests)):
            if i == j or not (set(ests[i].distinct) & set(ests[j].distinct)):
                continue
            e = join_est(ests[i], ests[j])
            # prefer iterating the bigger relation first (build on the smaller)
            if e.card < best_card or (
                e.card == best_card and best_pair and ests[i].card > ests[best_pair[0]].card
            ):
                best_pair, best_card = (i, j), e.card
    if best_pair is None:
        best_pair = (0, 1) if len(ests) > 1 else (0, 0)
    cur = join_est(ests[best_pair[0]], ests[best_pair[1]]) if len(ests) > 1 else ests[0]
    used = set(best_pair)
    order = [query.atoms[best_pair[0]]] + ([query.atoms[best_pair[1]]] if len(ests) > 1 else [])
    while len(used) < len(ests):
        best_k, best_e = None, None
        for k in range(len(ests)):
            if k in used:
                continue
            connected = bool(set(ests[k].distinct) & set(cur.distinct))
            e = join_est(cur, ests[k])
            key = (not connected, e.card)
            if best_e is None or key < best_e:
                best_k, best_e = k, key
        used.add(best_k)
        order.append(query.atoms[best_k])
        cur = join_est(cur, ests[best_k])
    return linear(order)


# ---------------------------------------------------------------------------
# Per-prefix estimates along a Free Join plan (Sec 4.3/4.4 batched execution:
# the compiled path sizes its static frontier buffers from these).
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class NodeEstimate:
    """Frontier-size estimates around one executed plan node: `expand` is
    the frontier right after the cover's trie level is iterated (this bounds
    the expansion buffer), `probe_after[j]` the live frontier once the
    node's first j+1 probes have filtered it, and `after` the frontier when
    the whole node is done. probe_after drives compaction decisions —
    including mid-node, between two probes of a factored plan."""

    node: int  # index into plan.nodes
    expand: float
    after: float
    probe_after: tuple[float, ...] = ()


def prefix_card(prefix: dict[str, tuple[str, ...]], stats: Stats) -> float:
    """Estimated size of the join of each relation's consumed var-prefix.

    A depth-d trie level holds the distinct prefix combos, bounded by both
    the relation's row count and the product of per-var distinct counts
    (independence); the prefixes are then joined with the same max-distinct
    rule as the binary estimator."""
    cur: Est | None = None
    for alias, vars_ in prefix.items():
        if not vars_:
            continue
        d = {v: stats.distinct(alias, v) for v in vars_}
        card = min(float(max(1, stats.size(alias))), float(np.prod(list(d.values()))))
        e = Est(card, d, [])
        cur = e if cur is None else join_est(cur, e)
    return 1.0 if cur is None else cur.card


def estimate_prefixes(
    plan: FreeJoinPlan,
    relations: dict[str, Relation] | None = None,
    *,
    stats: Stats | None = None,
    schedule=None,
) -> list[NodeEstimate]:
    """Walk the plan with the compiled path's static schedule (first-listed
    cover per node) and estimate the frontier size around every executed
    node. One entry per executed node, aligned with the compiled schedule.

    `stats` and `schedule` let the driver share one Stats cache and one
    StaticSchedule across the whole planning pass; passing only `relations`
    keeps the standalone surface working (stats built here)."""
    from repro.core.compiled import _static_schedule  # deferred: avoids a cycle

    if stats is None:
        stats = Stats(relations)
    if schedule is None:
        schedule = _static_schedule(plan)
    aliases = {sa.alias for node in plan.nodes for sa in node}
    prefix: dict[str, tuple[str, ...]] = {a: () for a in aliases}
    out: list[NodeEstimate] = []
    for k, cover, probes in schedule.entries:
        prefix[cover.alias] = prefix[cover.alias] + tuple(cover.vars)
        expand = prefix_card(prefix, stats)
        cards = []
        for sa in probes:
            prefix[sa.alias] = prefix[sa.alias] + tuple(sa.vars)
            cards.append(min(prefix_card(prefix, stats), expand))
        after = cards[-1] if cards else expand
        out.append(
            NodeEstimate(node=k, expand=expand, after=after, probe_after=tuple(cards))
        )
    return out
