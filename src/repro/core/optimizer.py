"""A small cost-based optimizer producing binary join plans.

The paper uses DuckDB's optimizer; DuckDB is not available in this
container, so we implement the classic textbook estimator: greedy left-deep
join ordering driven by cardinality estimates
|L join R| = |L|*|R| / prod_{v shared} max(d_L(v), d_R(v)).

`bad=True` reproduces the paper's Sec 5.4 hijack — every cardinality
estimate is pinned to 1 — under which the greedy search degenerates to
input order and we emit a *bushy* balanced tree (the paper observes DuckDB
"routinely outputs bushy plans that materialize large results" in this
regime).
"""
from __future__ import annotations

import numpy as np

from repro.core.plan import BinaryPlan, linear
from repro.relational.relation import Relation
from repro.relational.schema import Atom, Query


class _Est:
    def __init__(self, card: float, distinct: dict[str, float], atoms: list[Atom]):
        self.card = card
        self.distinct = distinct
        self.atoms = atoms


def _base_est(atom: Atom, rel: Relation, bad: bool) -> _Est:
    if bad:
        return _Est(1.0, {v: 1.0 for v in atom.vars}, [atom])
    d = {v: float(max(1, len(np.unique(rel.columns[v])))) for v in atom.vars}
    return _Est(float(max(1, rel.num_rows)), d, [atom])


def _join_est(a: _Est, b: _Est) -> _Est:
    shared = set(a.distinct) & set(b.distinct)
    denom = 1.0
    for v in shared:
        denom *= max(a.distinct[v], b.distinct[v])
    card = max(1.0, a.card * b.card / max(1.0, denom))
    d = dict(a.distinct)
    for v, dv in b.distinct.items():
        d[v] = min(d.get(v, float("inf")), dv, card)
    d = {v: min(dv, card) for v, dv in d.items()}
    return _Est(card, d, a.atoms + b.atoms)


def optimize(query: Query, relations: dict[str, Relation], bad: bool = False) -> BinaryPlan:
    ests = [_base_est(a, relations[a.alias], bad) for a in query.atoms]
    if bad:
        # balanced bushy over input order (all estimates tie at 1)
        nodes: list = list(query.atoms)
        while len(nodes) > 1:
            nxt = []
            for i in range(0, len(nodes) - 1, 2):
                nxt.append(BinaryPlan(nodes[i], nodes[i + 1]))
            if len(nodes) % 2:
                nxt.append(nodes[-1])
            nodes = nxt
        return nodes[0] if isinstance(nodes[0], BinaryPlan) else BinaryPlan(nodes[0], nodes[0])
    # greedy left-deep: best starting pair, then best extension
    best_pair, best_card = None, float("inf")
    for i in range(len(ests)):
        for j in range(len(ests)):
            if i == j or not (set(ests[i].distinct) & set(ests[j].distinct)):
                continue
            e = _join_est(ests[i], ests[j])
            # prefer iterating the bigger relation first (build on the smaller)
            if e.card < best_card or (
                e.card == best_card and best_pair and ests[i].card > ests[best_pair[0]].card
            ):
                best_pair, best_card = (i, j), e.card
    if best_pair is None:
        best_pair = (0, 1) if len(ests) > 1 else (0, 0)
    cur = _join_est(ests[best_pair[0]], ests[best_pair[1]]) if len(ests) > 1 else ests[0]
    used = set(best_pair)
    order = [query.atoms[best_pair[0]]] + ([query.atoms[best_pair[1]]] if len(ests) > 1 else [])
    while len(used) < len(ests):
        best_k, best_e = None, None
        for k in range(len(ests)):
            if k in used:
                continue
            connected = bool(set(ests[k].distinct) & set(cur.distinct))
            e = _join_est(cur, ests[k])
            key = (not connected, e.card)
            if best_e is None or key < best_e:
                best_k, best_e = k, key
        used.add(best_k)
        order.append(query.atoms[best_k])
        cur = _join_est(cur, ests[best_k])
    return linear(order)
