"""Cost-based join-order optimization: enumerate -> cost -> feedback.

The paper uses DuckDB's optimizer; DuckDB is not available in this
container, so plan choice is ours. Three layers, each feeding the next:

1. **Enumerate.** `JoinOrderOptimizer` runs dynamic programming over
   connected sub-queries (DPsub-style: every connected subset of atoms,
   every connected split of it, no cross products) and keeps the top-k
   candidate *bushy* binary trees per subset, ranked by the classic C_out
   cost with every per-subset cardinality capped by the AGM bound of that
   subset — one bad estimate cannot blow up the ranking. The enumeration
   pays at most `budget` (subset, split) pairs; past the budget — or at
   `level=0` — it falls back to `optimize`, the original greedy left-deep
   search driven by |L join R| = |L|*|R| / prod_{v shared} max(d_L, d_R).

2. **Cost.** The surviving candidates (plus the greedy tree, which wins
   ties for stability) are re-ranked by a *device* cost model
   (`device_cost`): capacity.plan_chain_capacities sizes every frontier
   buffer the compiled chain would allocate — estimates x safety, capped
   per prefix by the AGM bound — and the cost is the total number of
   frontier cells *touched*: one buffer-wide pass per expansion, per
   probe (at the compacted width once the plan compacts), per compaction
   scatter, plus the write + sort of every non-root stage's output
   buffer. That is the quantity a TPU actually pays for; output row
   counts alone would miss that a bushy stage trades frontier width for
   a trie build.

3. **Feedback.** The compiled executor reports every node's exact
   frontier need; the adaptive runner records them in
   relcache.FEEDBACK (a per-relation measured-cardinality store), and
   both the DP's subset cardinalities and the capacity planner's prefix
   estimates (`prefix_card`) consult it — so the next cold plan for these
   relations is chosen against measured, not estimated, cardinalities.
   Chosen plans are memoized per (query, relations): at the default
   level 1 the first choice is *pinned* for the life of the relations
   (one run measures only the chosen plan's own prefixes, so re-ranking
   against unmeasured challengers is information-asymmetric and every
   plan flip is a recompile); at level >= 2 a version bump of the store
   triggers re-planning, and the incumbent is abandoned only when the
   re-ranked best is decisively cheaper (`adopt_margin`) — it re-plans
   exactly when the measurements contradict the estimates.

`bad=True` reproduces the paper's Sec 5.4 hijack — every cardinality
estimate is pinned to 1 — under which the greedy search degenerates to
input order and we emit a *bushy* balanced tree (the paper observes DuckDB
"routinely outputs bushy plans that materialize large results" in this
regime).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from repro.core import relcache
from repro.core.plan import BinaryPlan, FreeJoinPlan, linear
from repro.relational.relation import Relation
from repro.relational.schema import Atom, Query


class Est:
    """A cardinality estimate: expected row count plus per-variable distinct
    counts (the state threaded through the System-R style estimator)."""

    def __init__(self, card: float, distinct: dict[str, float], atoms: list[Atom]):
        self.card = card
        self.distinct = distinct
        self.atoms = atoms


class Stats:
    """Per-column statistics shared across one query's whole planning pass
    (optimize -> plan_capacities -> estimate_prefixes): each referenced
    column is np.unique'd exactly once and the result cached. Holds a live
    reference to the driver's relation dict, so stage relations materialized
    mid-query are visible without rebuilding the cache.

    cached=True additionally persists each distinct count in the process-
    wide weakref registry (core/relcache.py), keyed by relation + column
    object identity — the compiled driver's steady-state surface, where a
    repeated query over the same relations pays zero np.unique calls. The
    default stays per-instance so eager-path callers keep the one-pass
    contract without touching global state."""

    def __init__(self, relations: dict[str, Relation], *, cached: bool = False):
        self.relations = relations
        self._distinct: dict[tuple[str, str], float] = {}
        self._cached = cached

    def size(self, alias: str) -> int:
        # live rows, not physical rows: a mutating relation's tombstones
        # weigh nothing in the trie, so capacity/cost estimates that counted
        # them would oversize every delta-maintained buffer
        from repro.core import relcache

        return relcache.live_size(self.relations[alias])

    def distinct(self, alias: str, var: str) -> float:
        key = (alias, var)
        if key not in self._distinct:
            rel = self.relations[alias]
            col = rel.columns[var]

            def compute():
                return float(max(1, len(np.unique(col))))

            if self._cached:
                from repro.core import relcache

                self._distinct[key] = relcache.memo(
                    relcache.REGISTRY, rel, "distinct", var, col, compute
                )
            else:
                self._distinct[key] = compute()
        return self._distinct[key]

    def relation_of(self, alias: str) -> Relation | None:
        """The live relation behind an alias, or None when the alias has no
        host relation (measured-cardinality feedback keys on relation
        identity, so only alias with a real object can use the store)."""
        return self.relations.get(alias)


class StageStats:
    """Statistics view that also answers for *planned* stage outputs —
    relations that never exist on the host, because the chained compiled
    path materializes them only as device buffers. A stage's size and
    per-var distinct counts come from the optimizer's Est of its sub-query
    (register() after planning the stage, before any downstream stage reads
    it); every other alias falls through to the base Stats cache, so the
    whole chain still costs one np.unique per referenced base column."""

    def __init__(self, base: Stats):
        self.base = base
        self._stage: dict[str, Est] = {}

    def register(self, alias: str, est: Est) -> None:
        self._stage[alias] = est

    def size(self, alias: str) -> int:
        if alias in self._stage:
            return int(max(1.0, self._stage[alias].card))
        return self.base.size(alias)

    def distinct(self, alias: str, var: str) -> float:
        if alias in self._stage:
            e = self._stage[alias]
            return float(min(max(1.0, e.distinct.get(var, e.card)), max(1.0, e.card)))
        return self.base.distinct(alias, var)

    def relation_of(self, alias: str) -> Relation | None:
        # stage outputs live only on device — no identity to key feedback on
        if alias in self._stage:
            return None
        return self.base.relation_of(alias)


class FilteredStats:
    """Statistics view for a query carrying equality selections (the serving
    path's plan *templates*: `v = ?` with the constant lifted out of the
    plan). A filtered variable contributes exactly one distinct value, and
    every atom containing it shrinks by that column's selectivity
    (size / distinct), so capacity planning sizes frontier buffers for the
    *selected* slice instead of the whole relation — the difference between
    a batched probe lane costing O(rows-matching-constant) and
    O(all-rows). Deliberately value-agnostic: the estimates depend only on
    WHICH vars are filtered, never on the constants, so every query of a
    template shares one plan and one executor.

    `filtered` maps alias -> the set of that atom's filtered vars. Plan
    choice (optimize) should keep using the unfiltered base stats — the
    binary plan must be template-stable too; this view feeds capacity
    planning, where an under-estimate is recovered by the adaptive runner's
    exact-need growth."""

    def __init__(self, base, filtered: dict[str, frozenset[str]]):
        self.base = base
        self.filtered = {a: frozenset(vs) for a, vs in filtered.items() if vs}

    def size(self, alias: str) -> int:
        s = float(max(1, self.base.size(alias)))
        for v in self.filtered.get(alias, ()):
            s /= max(1.0, self.base.distinct(alias, v))
        return int(max(1.0, math.ceil(s)))

    def distinct(self, alias: str, var: str) -> float:
        if var in self.filtered.get(alias, frozenset()):
            return 1.0
        return float(min(self.base.distinct(alias, var), max(1, self.size(alias))))

    def relation_of(self, alias: str) -> Relation | None:
        # a filtered atom's frontier contribution depends on the constant;
        # measured (unfiltered) cardinalities would oversize it
        if alias in self.filtered:
            return None
        return self.base.relation_of(alias)


def stage_est(atoms: list[Atom], stats) -> Est:
    """Estimated output of joining `atoms` (a stage sub-query): fold the
    binary estimator left to right. `stats` may be a StageStats so earlier
    stages' estimates flow into later stages'."""
    cur = base_est(atoms[0], stats)
    for a in atoms[1:]:
        cur = join_est(cur, base_est(a, stats))
    return cur


def base_est(atom: Atom, stats: Stats, bad: bool = False) -> Est:
    if bad:
        return Est(1.0, {v: 1.0 for v in atom.vars}, [atom])
    d = {v: stats.distinct(atom.alias, v) for v in atom.vars}
    return Est(float(max(1, stats.size(atom.alias))), d, [atom])


def join_est(a: Est, b: Est) -> Est:
    shared = set(a.distinct) & set(b.distinct)
    denom = 1.0
    for v in shared:
        denom *= max(a.distinct[v], b.distinct[v])
    card = max(1.0, a.card * b.card / max(1.0, denom))
    d = dict(a.distinct)
    for v, dv in b.distinct.items():
        d[v] = min(d.get(v, float("inf")), dv, card)
    d = {v: min(dv, card) for v, dv in d.items()}
    return Est(card, d, a.atoms + b.atoms)


def optimize(
    query: Query,
    relations: dict[str, Relation],
    bad: bool = False,
    *,
    stats: Stats | None = None,
) -> BinaryPlan | Atom:
    if stats is None:
        stats = Stats(relations)
    ests = [base_est(a, stats, bad) for a in query.atoms]
    if bad:
        # balanced bushy over input order (all estimates tie at 1)
        nodes: list = list(query.atoms)
        while len(nodes) > 1:
            nxt = []
            for i in range(0, len(nodes) - 1, 2):
                nxt.append(BinaryPlan(nodes[i], nodes[i + 1]))
            if len(nodes) % 2:
                nxt.append(nodes[-1])
            nodes = nxt
        return nodes[0]  # single-atom queries get the atom, not a self-join
    # greedy left-deep: best starting pair, then best extension
    best_pair, best_card = None, float("inf")
    for i in range(len(ests)):
        for j in range(len(ests)):
            if i == j or not (set(ests[i].distinct) & set(ests[j].distinct)):
                continue
            e = join_est(ests[i], ests[j])
            # prefer iterating the bigger relation first (build on the smaller)
            if e.card < best_card or (
                e.card == best_card and best_pair and ests[i].card > ests[best_pair[0]].card
            ):
                best_pair, best_card = (i, j), e.card
    if best_pair is None:
        best_pair = (0, 1) if len(ests) > 1 else (0, 0)
    cur = join_est(ests[best_pair[0]], ests[best_pair[1]]) if len(ests) > 1 else ests[0]
    used = set(best_pair)
    order = [query.atoms[best_pair[0]]] + ([query.atoms[best_pair[1]]] if len(ests) > 1 else [])
    while len(used) < len(ests):
        best_k, best_e = None, None
        for k in range(len(ests)):
            if k in used:
                continue
            connected = bool(set(ests[k].distinct) & set(cur.distinct))
            e = join_est(cur, ests[k])
            key = (not connected, e.card)
            if best_e is None or key < best_e:
                best_k, best_e = k, key
        used.add(best_k)
        order.append(query.atoms[best_k])
        cur = join_est(cur, ests[best_k])
    return linear(order)


# ---------------------------------------------------------------------------
# Per-prefix estimates along a Free Join plan (Sec 4.3/4.4 batched execution:
# the compiled path sizes its static frontier buffers from these).
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class NodeEstimate:
    """Frontier-size estimates around one executed plan node: `expand` is
    the frontier right after the cover's trie level is iterated (this bounds
    the expansion buffer), `probe_after[j]` the live frontier once the
    node's first j+1 probes have filtered it, and `after` the frontier when
    the whole node is done. probe_after drives compaction decisions —
    including mid-node, between two probes of a factored plan."""

    node: int  # index into plan.nodes
    expand: float
    after: float
    probe_after: tuple[float, ...] = ()


def prefix_card(
    prefix: dict[str, tuple[str, ...]], stats: Stats, feedback=None
) -> float:
    """Estimated size of the join of each relation's consumed var-prefix.

    A depth-d trie level holds the distinct prefix combos, bounded by both
    the relation's row count and the product of per-var distinct counts
    (independence); the prefixes are then joined with the same max-distinct
    rule as the binary estimator.

    `feedback` (a relcache.CardFeedback) short-circuits the estimate with
    the *measured* cardinality of this exact prefix multiset when a prior
    run recorded one — but only when every participating alias resolves to
    a live relation object (stats.relation_of), so stage outputs and
    constant-filtered atoms keep their estimates."""
    if feedback is not None:
        specs: list | None = []
        for alias, vars_ in prefix.items():
            if not vars_:
                continue
            rel = stats.relation_of(alias) if hasattr(stats, "relation_of") else None
            if rel is None:
                specs = None
                break
            specs.append((rel, vars_))
        if specs:
            measured = feedback.lookup(specs)
            if measured is not None:
                return float(max(1.0, measured))
    cur: Est | None = None
    for alias, vars_ in prefix.items():
        if not vars_:
            continue
        d = {v: stats.distinct(alias, v) for v in vars_}
        card = min(float(max(1, stats.size(alias))), float(np.prod(list(d.values()))))
        e = Est(card, d, [])
        cur = e if cur is None else join_est(cur, e)
    return 1.0 if cur is None else cur.card


def estimate_prefixes(
    plan: FreeJoinPlan,
    relations: dict[str, Relation] | None = None,
    *,
    stats: Stats | None = None,
    schedule=None,
    feedback=None,
) -> list[NodeEstimate]:
    """Walk the plan with the compiled path's static schedule (first-listed
    cover per node) and estimate the frontier size around every executed
    node. One entry per executed node, aligned with the compiled schedule.

    `stats` and `schedule` let the driver share one Stats cache and one
    StaticSchedule across the whole planning pass; passing only `relations`
    keeps the standalone surface working (stats built here). `feedback`
    replaces individual prefix estimates with measured cardinalities from
    prior runs where available (see prefix_card)."""
    from repro.core.compiled import _static_schedule  # deferred: avoids a cycle

    if stats is None:
        stats = Stats(relations)
    if schedule is None:
        schedule = _static_schedule(plan)
    aliases = {sa.alias for node in plan.nodes for sa in node}
    prefix: dict[str, tuple[str, ...]] = {a: () for a in aliases}
    out: list[NodeEstimate] = []
    for k, cover, probes in schedule.entries:
        prefix[cover.alias] = prefix[cover.alias] + tuple(cover.vars)
        expand = prefix_card(prefix, stats, feedback)
        cards = []
        for sa in probes:
            prefix[sa.alias] = prefix[sa.alias] + tuple(sa.vars)
            cards.append(min(prefix_card(prefix, stats, feedback), expand))
        after = cards[-1] if cards else expand
        out.append(
            NodeEstimate(node=k, expand=expand, after=after, probe_after=tuple(cards))
        )
    return out


# ---------------------------------------------------------------------------
# Cost-based plan enumeration: DP over connected subqueries + a device cost
# model over planned frontier capacities (see module docstring, layers 1-2).
# ---------------------------------------------------------------------------


def _tree_sig(tree) -> tuple:
    """Structural identity of a binary plan tree (BinaryPlan has no value
    equality; plan choice needs one to detect 'same plan as last time')."""
    if isinstance(tree, Atom):
        return (tree.alias,)
    return (_tree_sig(tree.left), _tree_sig(tree.right))


def device_cost(
    query: Query,
    tree,
    *,
    stats,
    safety: float = 2.0,
    compact_threshold: float = 0.25,
    feedback=None,
) -> float:
    """Device cost of one candidate plan tree, in frontier cells *touched*.

    The tree is decomposed into its compiled stage chain and capacity-
    planned exactly as execution would (capacity.plan_chain_capacities:
    estimates x safety capped per prefix by the AGM bound, measured
    cardinalities from `feedback` where available). The cost then charges
    one buffer-wide pass per expansion, one per probe — at the compacted
    width for probes after the plan's compact point — one per compaction
    scatter, and write + sort passes for every non-root stage's output
    buffer (the next stage's trie build scales with that static width).
    This is what distinguishes a bushy split from a left-deep chain on
    device: the bushy plan pays two small stage buffers and a trie build
    instead of dragging one huge intermediate frontier through every
    remaining probe."""
    from repro.core.capacity import plan_chain_capacities  # deferred: cycle
    from repro.core.plan import stage_plans

    stages = stage_plans(query, tree)
    chain = plan_chain_capacities(
        stages,
        stats=stats,
        safety=safety,
        compact_threshold=compact_threshold,
        feedback=feedback,
    )
    total = 0.0
    for si, cp in enumerate(chain.stages):
        for (_k, _cover, probes), cap, ct, cpi in zip(
            cp.schedule.entries, cp.capacities, cp.compact_to, cp.compact_probe
        ):
            total += cap  # the expansion writes the frontier once
            width = cap
            for j in range(len(probes)):
                if ct is not None and j >= cpi:
                    width = ct  # probes after the compact point run squeezed
                total += width  # one gather pass over the frontier per probe
            if ct is not None:
                total += cap  # the compaction scatter itself
        if si < len(chain.stages) - 1:
            out_w = cp.compact_to[-1] if cp.compact_to[-1] is not None else cp.capacities[-1]
            total += 2.0 * out_w  # stage output write + downstream trie sort
    return total


# chosen plans, memoized per (query structure, relation identities, knobs)
# and revalidated against the feedback store's version: a steady-state
# stream of identical queries re-enumerates nothing
_CHOICE_CACHE = relcache.KeyedCache(max_entries=128)


class JoinOrderOptimizer:
    """Enumerate -> cost -> feedback plan choice (module docstring).

    level 0 delegates to the greedy `optimize`; level >= 1 runs the DP
    enumeration with the default budget and PINS the choice (measured
    cardinalities sharpen later *cold* plans and capacity planning, but a
    live (query, relations) pair keeps its first plan — no recompiles);
    level >= 2 additionally enumerates with an effectively exhaustive
    budget and RE-PLANS when new measurements arrive, guarded by
    `adopt_margin` hysteresis. `budget` (max (subset, split) pairs
    considered) overrides the level default; exhausting it falls back to
    greedy. `keep` is the number of candidate trees retained per connected
    subset AND the number of finalists re-ranked by device_cost.
    `feedback` is a relcache.CardFeedback (usually relcache.FEEDBACK);
    `adopt_margin` is the hysteresis: a re-ranking under new measurements
    must beat the incumbent's device cost by this factor to displace it.
    `debug_lint` runs the static plan verifier (repro.analysis.planlint)
    over every device-costed finalist and raises on the first invalid one
    — an enumeration bug surfaces at the enumerator, named, instead of as
    a wrong winner three layers later. Off by default: it lints `keep`+1
    whole stage chains per cold choice."""

    def __init__(
        self,
        level: int = 1,
        *,
        budget: int | None = None,
        keep: int = 3,
        safety: float = 2.0,
        compact_threshold: float = 0.25,
        feedback=None,
        adopt_margin: float = 0.8,
        debug_lint: bool = False,
    ):
        self.level = int(level)
        self.budget = int(
            budget if budget is not None else (4096 if self.level <= 1 else 1 << 20)
        )
        self.keep = int(keep)
        self.safety = float(safety)
        self.compact_threshold = float(compact_threshold)
        self.feedback = feedback
        self.adopt_margin = float(adopt_margin)
        self.debug_lint = bool(debug_lint)

    # ---- public surface ----------------------------------------------
    def choose(
        self,
        query: Query,
        relations: dict[str, Relation],
        *,
        stats: Stats | None = None,
        bad: bool = False,
    ) -> BinaryPlan | Atom:
        if stats is None:
            stats = Stats(relations)
        if bad or self.level <= 0 or len(query.atoms) < 3:
            # greedy fallback: level 0, the Sec 5.4 hijack, and queries too
            # small for the enumeration to beat the heuristic
            return optimize(query, relations, bad, stats=stats)
        key = self._choice_key(query, relations)
        version = self.feedback.version if self.feedback is not None else 0
        hit = _CHOICE_CACHE.get(key)
        if hit is not None and (self.level < 2 or hit[1] == version):
            # level < 2 PINS the first choice for the life of the relations:
            # one run's measurements cover only the incumbent's own prefixes,
            # so re-ranking against unmeasured challengers is information-
            # asymmetric (the measured plan always looks worse than the
            # fantasy ones) and would flip-flop plans — and every flip is a
            # recompile. Level >= 2 opts into adaptive re-planning, guarded
            # by adopt_margin hysteresis below.
            return hit[0]
        chosen = self._choose_uncached(query, relations, stats, incumbent=hit)
        _CHOICE_CACHE.put(
            key, (chosen, version), [relations[a.alias] for a in query.atoms]
        )
        return chosen

    # ---- internals ----------------------------------------------------
    def _choice_key(self, query: Query, relations) -> tuple:
        return (
            tuple((a.alias, a.name, tuple(a.vars)) for a in query.atoms),
            tuple(query.head),
            self.level,
            self.budget,
            self.keep,
            round(self.safety, 6),
            round(self.compact_threshold, 6),
            tuple(sorted((a.alias, id(relations[a.alias])) for a in query.atoms)),
        )

    def _lint_finalists(self, query, finalists) -> None:
        """debug_lint mode: every enumerated finalist must derive a valid
        stage chain. A finding here is an enumerator/stage-derivation bug,
        so raise with the tree's signature in the message."""
        from repro.analysis.diagnostics import PlanVerificationError
        from repro.analysis.planlint import lint_chain, lint_tree

        for t, sig in finalists:
            rep, stages = lint_tree(query, t)
            if stages is not None:
                rep.extend(lint_chain(stages))
            if not rep.ok:
                rep.error(
                    "enumerated-plan-invalid",
                    f"finalist[{sig}]",
                    "device-costed finalist fails static verification",
                )
                raise PlanVerificationError(rep)

    def _choose_uncached(self, query, relations, stats, *, incumbent):
        fb = self.feedback
        greedy = optimize(query, relations, stats=stats)
        candidates = self._enumerate(query, stats)
        # greedy first: exact device-cost ties keep the pre-enumeration plan
        finalists, seen = [], set()
        for t in [greedy] + (candidates or []):
            sig = _tree_sig(t)
            if sig in seen:
                continue
            seen.add(sig)
            finalists.append((t, sig))
        if self.debug_lint:
            self._lint_finalists(query, finalists)
        if len(finalists) == 1:
            return finalists[0][0]
        costed = [
            (
                device_cost(
                    query,
                    t,
                    stats=stats,
                    safety=self.safety,
                    compact_threshold=self.compact_threshold,
                    feedback=fb,
                ),
                i,
                t,
                sig,
            )
            for i, (t, sig) in enumerate(finalists)
        ]
        cost, _i, best, best_sig = min(costed)
        if incumbent is not None:
            prev = incumbent[0]
            prev_sig = _tree_sig(prev)
            if prev_sig != best_sig:
                prev_cost = next(
                    (c for c, _i, _t, s in costed if s == prev_sig),
                    device_cost(
                        query,
                        prev,
                        stats=stats,
                        safety=self.safety,
                        compact_threshold=self.compact_threshold,
                        feedback=fb,
                    ),
                )
                if cost > self.adopt_margin * prev_cost:
                    # not decisively cheaper under the new measurements:
                    # keep the incumbent (a running template never swaps
                    # its compiled runner over estimation noise)
                    return prev
        return best

    def _enumerate(self, query: Query, stats) -> list | None:
        """Top-`keep` bushy trees for the full query by C_out cost with
        AGM-capped (and measured, where known) subset cardinalities; None
        when the budget runs out or the join graph is disconnected."""
        from repro.core.capacity import agm_bound  # deferred: cycle

        fb = self.feedback
        atoms = list(query.atoms)
        m = len(atoms)
        vars_of = [frozenset(a.vars) for a in atoms]
        sizes = {a.alias: float(max(1, stats.size(a.alias))) for a in atoms}
        full = (1 << m) - 1
        # best[mask] = up to `keep` of (cost, counter, tree, Est, varset)
        best: dict[int, list] = {}
        for i, a in enumerate(atoms):
            best[1 << i] = [(0.0, i, a, base_est(a, stats), vars_of[i])]
        tiebreak = m  # deterministic ordering for equal costs
        pairs = 0
        for mask in sorted(range(1, full + 1), key=lambda x: x.bit_count()):
            if mask.bit_count() < 2:
                continue
            members = [i for i in range(m) if mask >> i & 1]
            edges = {atoms[i].alias: tuple(atoms[i].vars) for i in members}
            bound = agm_bound(edges, sizes)
            measured = self._measured_card([atoms[i] for i in members], stats)
            cands: list = []
            sub = (mask - 1) & mask
            while sub:
                rest = mask ^ sub
                left, right = best.get(sub), best.get(rest)
                if left and right:
                    pairs += 1
                    if pairs > self.budget:
                        return None
                    cl, _tl, tl, el, vl = left[0]
                    cr, _tr, tr, er, vr = right[0]
                    if vl & vr:  # no cross products
                        est = join_est(el, er)
                        card = min(est.card, bound)
                        if measured is not None:
                            card = measured
                        est = Est(
                            card,
                            {v: min(dv, card) for v, dv in est.distinct.items()},
                            est.atoms,
                        )
                        tiebreak += 1
                        cands.append(
                            (cl + cr + card, tiebreak, BinaryPlan(tl, tr), est, vl | vr)
                        )
                sub = (sub - 1) & mask
            if cands:
                cands.sort(key=lambda c: (c[0], c[1]))
                dedup, sigs = [], set()
                for c in cands:
                    s = _tree_sig(c[2])
                    if s in sigs:
                        continue
                    sigs.add(s)
                    dedup.append(c)
                    if len(dedup) >= self.keep:
                        break
                best[mask] = dedup
        if full not in best:
            return None  # disconnected join graph: greedy handles it
        return [t for _c, _i, t, _e, _v in best[full]]

    def _measured_card(self, subset_atoms, stats) -> float | None:
        if self.feedback is None:
            return None
        specs = []
        for a in subset_atoms:
            rel = stats.relation_of(a.alias) if hasattr(stats, "relation_of") else None
            if rel is None:
                return None
            specs.append((rel, a.vars))
        return self.feedback.lookup(specs)
