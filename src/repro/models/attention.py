"""Grouped-query attention with the knobs the assigned archs need:
GQA/MQA kv-head counts, head_dim overrides (gemma: 256), qk-norm (qwen3),
QKV bias (qwen2), sliding windows (mixtral), RoPE theta, causal masking,
and a decode path over a preallocated KV cache.

Shapes: x (B, S, D); q (B, S, H, hd); kv (B, S, Hkv, hd); H % Hkv == 0.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    sliding_window: int | None = None  # None = full causal


def attn_init(key, cfg: AttnConfig, dtype=jnp.float32):
    kq, kk, kv, ko, kn1, kn2 = jax.random.split(key, 6)
    h, g, d, hd = cfg.num_heads, cfg.num_kv_heads, cfg.d_model, cfg.head_dim
    p = {
        "wq": layers._init_dense(kq, (d, h, hd), d, dtype),
        "wk": layers._init_dense(kk, (d, g, hd), d, dtype),
        "wv": layers._init_dense(kv, (d, g, hd), d, dtype),
        "wo": layers._init_dense(ko, (h, hd, d), h * hd, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((g, hd), dtype)
        p["bv"] = jnp.zeros((g, hd), dtype)
    if cfg.qk_norm:
        p["qnorm"] = layers.rmsnorm_init(hd, dtype)
        p["knorm"] = layers.rmsnorm_init(hd, dtype)
    return p


def _qkv(p, cfg: AttnConfig, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dgk->bsgk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dgk->bsgk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = layers.rmsnorm(p["qnorm"], q)
        k = layers.rmsnorm(p["knorm"], k)
    q = layers.rope(q, positions, cfg.rope_theta)
    k = layers.rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, cfg: AttnConfig):
    """q (B,S,H,hd), k/v (B,T,G,hd). Grouped: fold H into (G, H/G)."""
    b, s, h, hd = q.shape
    g = k.shape[2]
    q = q.reshape(b, s, g, h // g, hd)
    scores = jnp.einsum("bsgmk,btgk->bgmst", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgmst,btgk->bsgmk", probs, v)
    return out.reshape(b, s, h, hd)


def attn_apply(p, cfg: AttnConfig, x, positions, q_chunk: int = 0):
    """Full-sequence causal attention (train / prefill).

    With q_chunk > 0 and seq divisible, queries are processed in chunks of
    q_chunk rows (lax.scan): peak score memory drops from O(S^2) to
    O(q_chunk * S) per head — the long-sequence prefill shapes do not fit
    otherwise. (A Pallas flash kernel is the TPU endgame; chunking already
    removes the quadratic buffer, which is what the dry-run memory model
    sees.)"""
    q, k, v = _qkv(p, cfg, x, positions)
    s = x.shape[1]
    j = jnp.arange(s)[None, :]
    if q_chunk and s > q_chunk and s % q_chunk == 0:
        nc = s // q_chunk
        qc = q.reshape(q.shape[0], nc, q_chunk, *q.shape[2:]).transpose(1, 0, 2, 3, 4)

        def one(carry, args):
            ci, qblk = args
            i = ci * q_chunk + jnp.arange(q_chunk)[:, None]
            mask = j <= i
            if cfg.sliding_window is not None:
                mask = mask & (j > i - cfg.sliding_window)
            mask = jnp.broadcast_to(mask, (x.shape[0], q_chunk, s))
            return carry, _sdpa(qblk, k, v, mask, cfg)

        _, outs = jax.lax.scan(one, None, (jnp.arange(nc), qc))
        out = outs.transpose(1, 0, 2, 3, 4).reshape(x.shape[0], s, q.shape[2], q.shape[3])
    else:
        i = jnp.arange(s)[:, None]
        mask = j <= i
        if cfg.sliding_window is not None:
            mask = mask & (j > i - cfg.sliding_window)
        mask = jnp.broadcast_to(mask, (x.shape[0], s, s))
        out = _sdpa(q, k, v, mask, cfg)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def attn_decode(p, cfg: AttnConfig, x, cache_k, cache_v, cur_len):
    """One-token decode. x (B, 1, D); cache_k/v (B, T, G, hd); cur_len ()
    or (B,) int32 = per-sequence number of valid cache positions (vector
    form supports continuous batching of mixed-length requests).
    Returns (out, new_k, new_v).

    With a sliding window the cache is a rotating buffer of window size W:
    the new token overwrites slot cur_len % W.
    """
    b = x.shape[0]
    t = cache_k.shape[1]
    cur = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (b,))
    q, k, v = _qkv(p, cfg, x, cur[:, None])  # RoPE at absolute positions
    slot = cur % t if cfg.sliding_window is not None else jnp.minimum(cur, t - 1)
    bi = jnp.arange(b)
    cache_k = cache_k.at[bi, slot].set(k[:, 0].astype(cache_k.dtype))
    cache_v = cache_v.at[bi, slot].set(v[:, 0].astype(cache_v.dtype))
    j = jnp.arange(t)[None, :]
    valid = j <= slot[:, None]
    if cfg.sliding_window is not None:
        valid = valid | (cur[:, None] >= t)  # full rotating buffer
    mask = valid[:, None, :]
    out = _sdpa(q, cache_k.astype(q.dtype), cache_v.astype(q.dtype), mask, cfg)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype)), cache_k, cache_v
