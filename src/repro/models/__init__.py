from repro.models.transformer import ModelConfig, MoEConfig, init_params, apply_model
from repro.models import layers, attention, moe, ssm

__all__ = [
    "ModelConfig",
    "MoEConfig",
    "init_params",
    "apply_model",
    "layers",
    "attention",
    "moe",
    "ssm",
]
