"""Composable decoder-only LM covering all 10 assigned architectures.

A model is `num_layers` blocks; block i's mixer type comes from the
repeating `block_pattern` (("attn",) for dense archs, ("attn",) + 7*("mamba",)
for jamba, ("rwkv",) for rwkv6). The FFN of block i is MoE when
`moe.every_n` divides (i+1). Layers are *scanned* over repeats of the
pattern unit ("superblock") so HLO size and compile time stay O(pattern),
not O(num_layers) — essential for the 72-layer dry-run configs. Each
superblock is wrapped in jax.checkpoint (remat).

Modality frontends ([vlm]/[audio]) are stubs by assignment: `apply_model`
accepts either int32 token ids (embedded here) or precomputed float
embeddings (B, S, D) from input_specs().
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention, layers, moe, ssm
from repro.models.moe import MoEConfig


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    act: str = "swiglu"
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    sliding_window: int | None = None
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    tie_embeddings: bool = True
    moe: MoEConfig | None = None
    block_pattern: tuple[str, ...] = ("attn",)
    d_state: int = 16  # mamba
    frontend: str = "none"  # none | vlm | audio (stub: embeddings in)
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "none"  # none (recompute all) | dots (save matmul outs)
    attn_q_chunk: int = 1024  # query-chunked attention above this seq len
    scan_unroll: bool = False  # dry-run flops probes unroll the layer scan

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def repeats(self) -> int:
        assert self.num_layers % len(self.block_pattern) == 0, (
            f"{self.num_layers} layers not divisible by pattern {self.block_pattern}"
        )
        return self.num_layers // len(self.block_pattern)

    @property
    def attn_cfg(self) -> attention.AttnConfig:
        return attention.AttnConfig(
            d_model=self.d_model,
            num_heads=self.num_heads,
            num_kv_heads=self.num_kv_heads,
            head_dim=self.hd,
            qk_norm=self.qk_norm,
            qkv_bias=self.qkv_bias,
            rope_theta=self.rope_theta,
            sliding_window=self.sliding_window,
        )

    @property
    def mamba_cfg(self) -> ssm.MambaConfig:
        return ssm.MambaConfig(d_model=self.d_model, d_inner=2 * self.d_model, d_state=self.d_state)

    @property
    def rwkv_cfg(self) -> ssm.RWKV6Config:
        return ssm.RWKV6Config(d_model=self.d_model, num_heads=self.num_heads)

    def is_moe_layer(self, i: int) -> bool:
        return self.moe is not None and (i % self.moe.every_n) == (self.moe.every_n - 1)

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)


def _norm_init(cfg: ModelConfig):
    return (
        layers.rmsnorm_init(cfg.d_model, cfg.pdtype())
        if cfg.norm == "rmsnorm"
        else layers.layernorm_init(cfg.d_model, cfg.pdtype())
    )


def _norm_apply(cfg: ModelConfig, p, x):
    return layers.rmsnorm(p, x) if cfg.norm == "rmsnorm" else layers.layernorm(p, x)


def _init_block(key, cfg: ModelConfig, pos: int):
    """One block at pattern position `pos` (layer index pos within a unit)."""
    kind = cfg.block_pattern[pos]
    kmix, kffn = jax.random.split(key)
    dt = cfg.pdtype()
    p: dict[str, Any] = {"ln1": _norm_init(cfg), "ln2": _norm_init(cfg)}
    if kind == "attn":
        p["mixer"] = attention.attn_init(kmix, cfg.attn_cfg, dt)
    elif kind == "mamba":
        p["mixer"] = ssm.mamba_init(kmix, cfg.mamba_cfg, dt)
    elif kind == "rwkv":
        p["mixer"] = ssm.rwkv6_init(kmix, cfg.rwkv_cfg, dt)
    else:
        raise ValueError(kind)
    if kind == "rwkv":
        p["ffn"] = ssm.rwkv6_ffn_init(kffn, cfg.d_model, cfg.d_ff, dt)
    elif cfg.is_moe_layer(pos):
        p["ffn"] = moe.moe_init(kffn, cfg.d_model, cfg.moe, dt)
    else:
        p["ffn"] = layers.mlp_init(kffn, layers.MLPConfig(cfg.d_model, cfg.d_ff, cfg.act), dt)
    return p


def init_params(key, cfg: ModelConfig):
    if cfg.moe is not None:
        assert len(cfg.block_pattern) % cfg.moe.every_n == 0 or len(cfg.block_pattern) == 1
    ke, ku, kb = jax.random.split(key, 3)
    params: dict[str, Any] = {"embed": layers.embed_init(ke, cfg.vocab, cfg.d_model, cfg.pdtype())}
    if not cfg.tie_embeddings:
        params["embed"]["out"] = (
            jax.random.normal(ku, (cfg.vocab, cfg.d_model), jnp.float32).astype(cfg.pdtype()) * 0.02
        )
    params["final_norm"] = _norm_init(cfg)
    unit = len(cfg.block_pattern)

    def init_unit(k):
        kk = jax.random.split(k, unit)
        return tuple(_init_block(kk[p], cfg, p) for p in range(unit))

    params["blocks"] = jax.vmap(init_unit)(jax.random.split(kb, cfg.repeats))
    return params


def _block_apply(cfg: ModelConfig, pos: int, p, x, positions):
    kind = cfg.block_pattern[pos]
    h = _norm_apply(cfg, p["ln1"], x)
    if kind == "attn":
        h = attention.attn_apply(p["mixer"], cfg.attn_cfg, h, positions, cfg.attn_q_chunk)
    elif kind == "mamba":
        h = ssm.mamba_apply(p["mixer"], cfg.mamba_cfg, h)
    else:
        h = ssm.rwkv6_apply(p["mixer"], cfg.rwkv_cfg, h)
    x = x + h
    h = _norm_apply(cfg, p["ln2"], x)
    if kind == "rwkv":
        h_prev = jnp.pad(h, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        h = ssm.rwkv6_ffn(p["ffn"], h, h_prev)
    elif cfg.is_moe_layer(pos):
        h = moe.moe_apply(p["ffn"], cfg.moe, h)
    else:
        h = layers.mlp_apply(p["ffn"], h, cfg.act)
    return x + h


def apply_model(params, cfg: ModelConfig, inputs, positions=None, last_only: bool = False):
    """inputs: int32 token ids (B, S) or float embeddings (B, S, D).
    Returns fp32 logits (B, S, vocab)."""
    cdt = cfg.cdtype()
    x = (
        layers.embed_apply(params["embed"], inputs, cdt)
        if jnp.issubdtype(inputs.dtype, jnp.integer)
        else inputs.astype(cdt)
    )
    b, s = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def unit_apply(x, unit_params):
        x = layers.constrain(x, "act")
        for pos in range(len(cfg.block_pattern)):
            x = _block_apply(cfg, pos, unit_params[pos], x, positions)
        return x, None

    body = unit_apply
    if cfg.remat:
        policy = (
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            if cfg.remat_policy == "dots"
            else None  # recompute everything: only unit inputs are saved
        )
        body = jax.checkpoint(unit_apply, policy=policy)
    x, _ = jax.lax.scan(body, x, params["blocks"], unroll=cfg.scan_unroll)
    x = _norm_apply(cfg, params["final_norm"], x)
    if last_only:
        # serving prefill: only the final position's logits are needed —
        # skips the (tokens x vocab) logits tensor and its collectives
        x = x[:, -1:]
    return layers.unembed_apply(params["embed"], x, cfg.tie_embeddings)


# ---------------------------------------------------------------------------
# decode path with per-block caches
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    """Cache pytree: tuple over pattern positions; leaves stacked (R, ...).
    attn -> (k, v); mamba -> (conv_buf, h); rwkv -> (x_prev, state)."""
    dtype = dtype or cfg.cdtype()
    r = cfg.repeats
    caches = []
    for kind in cfg.block_pattern:
        if kind == "attn":
            w = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
            shape = (r, batch, w, cfg.num_kv_heads, cfg.hd)
            caches.append((jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)))
        elif kind == "mamba":
            m = cfg.mamba_cfg
            caches.append(
                (
                    jnp.zeros((r, batch, m.conv_width - 1, m.d_inner), dtype),
                    jnp.zeros((r, batch, m.d_inner, m.d_state), jnp.float32),
                )
            )
        else:  # rwkv: (x_prev time-mix, x_prev ffn, wkv state)
            rc = cfg.rwkv_cfg
            caches.append(
                (
                    jnp.zeros((r, batch, 1, cfg.d_model), dtype),
                    jnp.zeros((r, batch, 1, cfg.d_model), dtype),
                    jnp.zeros((r, batch, rc.num_heads, rc.head_dim, rc.head_dim), jnp.float32),
                )
            )
    return tuple(caches)


def decode_step(params, cfg: ModelConfig, token, cache, cur_len):
    """token (B, 1) int32 or embedding (B, 1, D); cur_len () int32.
    Returns (logits (B, 1, vocab), new_cache)."""
    cdt = cfg.cdtype()
    x = (
        layers.embed_apply(params["embed"], token, cdt)
        if jnp.issubdtype(token.dtype, jnp.integer)
        else token.astype(cdt)
    )

    def unit_step(x, scanned):
        x = layers.constrain(x, "act_dec")
        unit_params, unit_cache = scanned
        new_cache = []
        for pos, kind in enumerate(cfg.block_pattern):
            p, c = unit_params[pos], unit_cache[pos]
            h = _norm_apply(cfg, p["ln1"], x)
            if kind == "attn":
                h, nk, nv = attention.attn_decode(p["mixer"], cfg.attn_cfg, h, c[0], c[1], cur_len)
                nc = (nk, nv)
            elif kind == "mamba":
                h, buf, hs = ssm.mamba_decode(p["mixer"], cfg.mamba_cfg, h, c[0], c[1])
                nc = (buf, hs)
            else:
                h, xp, st = ssm.rwkv6_decode(p["mixer"], cfg.rwkv_cfg, h, c[0], c[2])
                nc = (xp, c[1], st)
            x = x + h
            h2 = _norm_apply(cfg, p["ln2"], x)
            if kind == "rwkv":
                out = ssm.rwkv6_ffn(p["ffn"], h2, nc[1])
                nc = (nc[0], h2, nc[2])
            elif cfg.is_moe_layer(pos):
                out = moe.moe_apply(p["ffn"], cfg.moe, h2)
            else:
                out = layers.mlp_apply(p["ffn"], h2, cfg.act)
            x = x + out
            new_cache.append(nc)
        return x, tuple(new_cache)

    x, new_cache = jax.lax.scan(unit_step, x, (params["blocks"], cache), unroll=cfg.scan_unroll)
    x = _norm_apply(cfg, params["final_norm"], x)
    return layers.unembed_apply(params["embed"], x, cfg.tie_embeddings), new_cache
