"""Core NN layers (pure functions over param pytrees, jnp only).

Conventions:
  * params are nested dicts of jnp arrays; init fns take an rng key.
  * compute dtype is the dtype of the activations passed in; norms and
    softmax run in fp32 and cast back (mixed-precision policy).
  * all matmuls are einsums with explicit dimension letters, so sharding
    rules in launch/sharding.py can target them by param path.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp


def _init_dense(key, shape, in_axis_size, dtype):
    scale = 1.0 / math.sqrt(in_axis_size)
    return (jax.random.uniform(key, shape, jnp.float32, -1.0, 1.0) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["scale"].astype(jnp.float32) - 1.0)).astype(x.dtype) * 1.0


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0):
    """x: (..., S, H, D) with D even; positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = jnp.exp(-jnp.arange(0, d, 2, dtype=jnp.float32) / d * math.log(theta))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MLPConfig:
    d_model: int
    d_ff: int
    act: str = "swiglu"  # swiglu | geglu | gelu


def mlp_init(key, cfg: MLPConfig, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    gated = cfg.act in ("swiglu", "geglu")
    p = {
        "wi": _init_dense(k1, (cfg.d_model, cfg.d_ff), cfg.d_model, dtype),
        "wo": _init_dense(k2, (cfg.d_ff, cfg.d_model), cfg.d_ff, dtype),
    }
    if gated:
        p["wg"] = _init_dense(k3, (cfg.d_model, cfg.d_ff), cfg.d_model, dtype)
    return p


def mlp_apply(p, x, act: str = "swiglu"):
    h = jnp.einsum("...d,df->...f", x, p["wi"].astype(x.dtype))
    if act == "swiglu":
        g = jnp.einsum("...d,df->...f", x, p["wg"].astype(x.dtype))
        h = jax.nn.silu(g) * h
    elif act == "geglu":
        g = jnp.einsum("...d,df->...f", x, p["wg"].astype(x.dtype))
        h = jax.nn.gelu(g, approximate=True) * h
    else:
        h = jax.nn.gelu(h, approximate=True)
    return jnp.einsum("...f,fd->...d", h, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------


def embed_init(key, vocab: int, d_model: int, dtype=jnp.float32):
    return {"table": jax.random.normal(key, (vocab, d_model), jnp.float32).astype(dtype) * 0.02}


def embed_apply(p, tokens: jnp.ndarray, compute_dtype):
    return jnp.take(p["table"].astype(compute_dtype), tokens, axis=0)


# -- activation sharding constraints (set by the launcher; no-ops on CPU) ---
# GSPMD propagation alone re-replicates some large activations (notably
# logits); the launcher pins the ones that matter here. This is a first-class
# perf lever: see EXPERIMENTS.md §Perf.
_CONSTRAINTS: dict[str, object] = {}


def set_constraint(name: str, sharding) -> None:
    _CONSTRAINTS[name] = sharding


def clear_constraints() -> None:
    _CONSTRAINTS.clear()


def constrain(x: jnp.ndarray, name: str) -> jnp.ndarray:
    s = _CONSTRAINTS.get(name)
    if s is None:
        return x
    return jax.lax.with_sharding_constraint(x, s)


def unembed_apply(p, x, tied: bool):
    table = p["table"] if tied else p["out"]
    # logits in fp32 (loss stability at 256k vocab), kept vocab-sharded
    logits = jnp.einsum("...d,vd->...v", x.astype(jnp.float32), table.astype(jnp.float32))
    return constrain(logits, "logits")
