"""Mixture-of-Experts with capacity-bounded scatter dispatch.

Relational note (DESIGN.md §5): top-k routing is a join between the token
table and the expert table, and the dispatch below is exactly the GHT build
primitive from the join engine — rank tokens within each expert group
(cumsum over a one-hot = the group-by rank in core/colt.py) and scatter
them into per-expert CSR-like buffers. Tokens beyond an expert's capacity
are dropped (residual connection carries them), the standard TPU-MoE
trade that keeps every shape static — the same capacity-with-overflow
discipline the compiled join engine uses for its frontier.

Supports top-k routing with renormalized gates, capacity factor, optional
dense residual branch (snowflake-arctic style), expert-parallel sharding
(experts dim is sharded over the `model`/`expert` mesh axis by the rules in
launch/sharding.py).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int = 2
    d_ff: int = 0  # expert hidden size
    capacity_factor: float = 1.25
    dense_residual: bool = False
    d_ff_dense: int = 0  # hidden size of the dense residual branch
    every_n: int = 1  # MoE every n-th layer (jamba: 2)
    act: str = "swiglu"


def moe_init(key, d_model: int, cfg: MoEConfig, dtype=jnp.float32):
    kr, ki, kg, ko, kd = jax.random.split(key, 5)
    e, f = cfg.num_experts, cfg.d_ff
    p = {
        "router": layers._init_dense(kr, (d_model, e), d_model, jnp.float32),
        "wi": layers._init_dense(ki, (e, d_model, f), d_model, dtype),
        "wg": layers._init_dense(kg, (e, d_model, f), d_model, dtype),
        "wo": layers._init_dense(ko, (e, f, d_model), f, dtype),
    }
    if cfg.dense_residual:
        p["dense"] = layers.mlp_init(
            kd, layers.MLPConfig(d_model, cfg.d_ff_dense or 2 * d_model, cfg.act), dtype
        )
    return p


def _capacity(tokens: int, cfg: MoEConfig) -> int:
    c = int(tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(4, c)


# §Perf H6: expert matmuls with compute-dtype backward accumulation. The
# default transpose accumulates partials in f32, so the (B,E,C,D) grad
# all-reduce over the model axis moves 2x the bytes. Casting the cotangent
# and forcing preferred_element_type keeps that reduce in bf16 (standard
# for activation grads); weight grads still accumulate in f32.
from functools import partial as _partial  # noqa: E402


@_partial(jax.custom_vjp, nondiff_argnums=(2,))
def _expert_mm(buf, w, sub: str):  # "in": becd,edf->becf | "out": becf,efd->becd
    eq = "becd,edf->becf" if sub == "in" else "becf,efd->becd"
    # compute-dtype accumulation: the "out" matmul contracts the TP-sharded
    # ffn dim, so its partial sums cross the model axis — keep them bf16
    return jnp.einsum(eq, buf, w, preferred_element_type=buf.dtype)


def _expert_mm_fwd(buf, w, sub: str):
    return _expert_mm(buf, w, sub), (buf, w)


def _expert_mm_bwd(sub, res, g):
    buf, w = res
    g = g.astype(buf.dtype)
    if sub == "in":
        dbuf = jnp.einsum("becf,edf->becd", g, w, preferred_element_type=buf.dtype)
        dw = jnp.einsum("becd,becf->edf", buf, g, preferred_element_type=jnp.float32)
    else:
        dbuf = jnp.einsum("becd,efd->becf", g, w, preferred_element_type=buf.dtype)
        dw = jnp.einsum("becf,becd->efd", buf, g, preferred_element_type=jnp.float32)
    return dbuf, dw.astype(w.dtype)


_expert_mm.defvjp(_expert_mm_fwd, _expert_mm_bwd)


def moe_apply(p, cfg: MoEConfig, x: jnp.ndarray):
    """x: (B, S, D). Dispatch groups are the batch dim (sharded over data),
    so capacity is per-sequence-group and no cross-device rank is needed."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    cap = _capacity(s, cfg)

    gates = jax.nn.softmax(
        jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"]), axis=-1
    )
    topv, tope = jax.lax.top_k(gates, k)  # (B, S, k)
    topv = topv / jnp.clip(topv.sum(-1, keepdims=True), 1e-9)  # renormalize

    def dispatch_one(xg, eg, vg):
        # xg (S, D); eg/vg (S, k) -> expert buffers (E, cap, D), combine meta
        flat_e = eg.reshape(-1)  # (S*k,) in token-major order
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) - 1  # rank within expert group
        pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
        keep = pos < cap
        tok = jnp.repeat(jnp.arange(s), k)
        buf = jnp.zeros((e, cap, d), x.dtype)
        buf = buf.at[
            jnp.where(keep, flat_e, e), jnp.where(keep, pos, 0)
        ].add(xg[tok], mode="drop")
        return buf, (flat_e, pos, keep, tok, vg.reshape(-1))

    buf, meta = jax.vmap(dispatch_one)(x, tope, topv)  # (B, E, cap, D)
    buf = layers.constrain(buf, "moe_buf")

    h = _expert_mm(buf, p["wi"].astype(x.dtype), "in")
    g = _expert_mm(buf, p["wg"].astype(x.dtype), "in")
    h = jax.nn.silu(g) * h if cfg.act == "swiglu" else jax.nn.gelu(g, approximate=True) * h
    out = _expert_mm(h, p["wo"].astype(x.dtype), "out")  # (B, E, cap, D)
    out = layers.constrain(out, "moe_out")

    def combine_one(outg, m):
        flat_e, pos, keep, tok, w = m
        gathered = outg[jnp.where(keep, flat_e, 0), jnp.where(keep, pos, 0)]
        gathered = jnp.where(keep[:, None], gathered, 0)
        return jnp.zeros((s, d), x.dtype).at[tok].add(gathered * w[:, None].astype(x.dtype))

    y = jax.vmap(combine_one)(out, meta)
    y = layers.constrain(y, "moe_y")
    if cfg.dense_residual:
        y = y + layers.mlp_apply(p["dense"], x, cfg.act)
    return y
