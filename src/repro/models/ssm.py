"""State-space & linear-attention blocks: Mamba (jamba's SSM half) and
RWKV-6 "Finch" (data-dependent decay).

Both are written as chunked/sequential scans with O(1) per-step state so
the `long_500k` decode shape is genuinely sub-quadratic:
  * Mamba: selective SSM. Full-seq path = lax.scan over chunks carrying the
    (B, d_inner, N) state, associative_scan inside each chunk (bounded
    transients instead of a (B, S, d_inner, N) blow-up).
  * RWKV-6: per-head matrix state S (hd x hd) with data-dependent diagonal
    decay w_t = exp(-exp(...)), token-shift mixing, bonus u, per-head
    group-norm. Full-seq path = lax.scan over time; decode carries
    (x_prev, S) only.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.models import layers


# ---------------------------------------------------------------------------
# Mamba
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_model: int
    d_inner: int  # usually 2 * d_model
    d_state: int = 16
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)
    conv_width: int = 4
    chunk: int = 256

    @property
    def rank(self) -> int:
        return self.dt_rank or max(1, math.ceil(self.d_model / 16))


def mamba_init(key, cfg: MambaConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    di, n, r = cfg.d_inner, cfg.d_state, cfg.rank
    return {
        "in_proj": layers._init_dense(ks[0], (cfg.d_model, 2 * di), cfg.d_model, dtype),
        "conv": layers._init_dense(ks[1], (cfg.conv_width, di), cfg.conv_width, dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": layers._init_dense(ks[2], (di, r + 2 * n), di, dtype),
        "dt_proj": layers._init_dense(ks[3], (r, di), r, dtype),
        "dt_bias": jnp.zeros((di,), dtype),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32), (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": layers._init_dense(ks[4], (di, cfg.d_model), di, dtype),
    }


def _mamba_scan(da, dbx, cfg: MambaConfig):
    """da, dbx: (B, S, di, N) decay and input terms. Chunked linear scan:
    h_t = da_t * h_{t-1} + dbx_t. Returns h over all t."""
    b, s, di, n = da.shape
    ck = min(cfg.chunk, s)
    nc = s // ck
    assert nc * ck == s, f"seq {s} must be divisible by chunk {ck}"
    da_c = da.reshape(b, nc, ck, di, n).transpose(1, 0, 2, 3, 4)
    dbx_c = dbx.reshape(b, nc, ck, di, n).transpose(1, 0, 2, 3, 4)

    def chunk_step(h0, inputs):
        a, bx = inputs  # (B, ck, di, N)

        def comb(x, y):
            a1, b1 = x
            a2, b2 = y
            return a1 * a2, a2 * b1 + b2

        aa, bb = jax.lax.associative_scan(comb, (a, bx), axis=1)
        h = aa * h0[:, None] + bb  # (B, ck, di, N)
        return h[:, -1], h

    h_last, hs = jax.lax.scan(chunk_step, jnp.zeros((b, di, n), da.dtype), (da_c, dbx_c))
    return hs.transpose(1, 0, 2, 3, 4).reshape(b, s, di, n)


def mamba_apply(p, cfg: MambaConfig, x):
    """x: (B, S, D) -> (B, S, D)."""
    b, s, _ = x.shape
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    xi, z = jnp.split(xz, 2, axis=-1)  # (B, S, di)
    # causal depthwise conv, window w
    w = cfg.conv_width
    pad = jnp.pad(xi, ((0, 0), (w - 1, 0), (0, 0)))
    conv = sum(
        pad[:, i : i + s] * p["conv"][i].astype(x.dtype) for i in range(w)
    ) + p["conv_b"].astype(x.dtype)
    xi = jax.nn.silu(conv)
    dbc = jnp.einsum("bsi,ie->bse", xi, p["x_proj"].astype(x.dtype))
    dt, bmat, cmat = jnp.split(dbc, [cfg.rank, cfg.rank + cfg.d_state], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt, p["dt_proj"].astype(x.dtype))
        + p["dt_bias"].astype(x.dtype)
    ).astype(jnp.float32)
    a = -jnp.exp(p["A_log"])  # (di, N)
    da = jnp.exp(dt[..., None] * a)  # (B, S, di, N)
    dbx = (dt * xi.astype(jnp.float32))[..., None] * bmat.astype(jnp.float32)[..., None, :]
    h = _mamba_scan(da.astype(jnp.float32), dbx, cfg)
    y = jnp.einsum("bsin,bsn->bsi", h, cmat.astype(jnp.float32))
    y = (y + p["D"] * xi.astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    return jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(x.dtype))


def mamba_decode(p, cfg: MambaConfig, x, conv_buf, h):
    """One-step decode. x (B, 1, D); conv_buf (B, w-1, di); h (B, di, N).
    Returns (y, conv_buf, h)."""
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    xi, z = jnp.split(xz, 2, axis=-1)
    window = jnp.concatenate([conv_buf, xi], axis=1)  # (B, w, di)
    conv = jnp.einsum("bwi,wi->bi", window, p["conv"].astype(x.dtype)) + p["conv_b"].astype(x.dtype)
    xi1 = jax.nn.silu(conv)[:, None]  # (B, 1, di)
    dbc = jnp.einsum("bsi,ie->bse", xi1, p["x_proj"].astype(x.dtype))
    dt, bmat, cmat = jnp.split(dbc, [cfg.rank, cfg.rank + cfg.d_state], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt, p["dt_proj"].astype(x.dtype)) + p["dt_bias"].astype(x.dtype)
    ).astype(jnp.float32)[:, 0]
    a = -jnp.exp(p["A_log"])
    da = jnp.exp(dt[..., None] * a)  # (B, di, N)
    dbx = (dt * xi1[:, 0].astype(jnp.float32))[..., None] * bmat.astype(jnp.float32)[:, 0][
        :, None, :
    ]
    h = da * h + dbx
    y = jnp.einsum("bin,bn->bi", h, cmat.astype(jnp.float32)[:, 0])
    y = (y + p["D"] * xi1[:, 0].astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z[:, 0])
    out = jnp.einsum("bi,id->bd", y, p["out_proj"].astype(x.dtype))[:, None]
    return out, window[:, 1:], h


# ---------------------------------------------------------------------------
# RWKV-6 (Finch)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RWKV6Config:
    d_model: int
    num_heads: int  # head_dim = d_model // num_heads
    decay_lora: int = 64

    @property
    def head_dim(self) -> int:
        return self.d_model // self.num_heads


def rwkv6_init(key, cfg: RWKV6Config, dtype=jnp.float32):
    ks = jax.random.split(key, 10)
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    return {
        "mu": 0.5 * jnp.ones((5, d), dtype),  # token-shift mixes for r,k,v,w,g
        "wr": layers._init_dense(ks[0], (d, d), d, dtype),
        "wk": layers._init_dense(ks[1], (d, d), d, dtype),
        "wv": layers._init_dense(ks[2], (d, d), d, dtype),
        "wg": layers._init_dense(ks[3], (d, d), d, dtype),
        "w0": jnp.zeros((d,), jnp.float32) - 4.0,  # base decay
        "wa": layers._init_dense(ks[4], (d, cfg.decay_lora), d, dtype),
        "wb": layers._init_dense(ks[5], (cfg.decay_lora, d), cfg.decay_lora, dtype),
        "u": jnp.zeros((h, hd), jnp.float32),  # bonus
        "wo": layers._init_dense(ks[6], (d, d), d, dtype),
        "ln_x": layers.layernorm_init(hd, dtype),  # per-head group norm
    }


def _rwkv6_proj(p, cfg: RWKV6Config, x, x_prev):
    """Token-shifted projections. x, x_prev: (B, S, D) where x_prev is x
    shifted right by one (or the carried last token in decode)."""
    mu = p["mu"].astype(x.dtype)
    mix = [x + mu[i] * (x_prev - x) for i in range(5)]
    r = jnp.einsum("bsd,de->bse", mix[0], p["wr"].astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", mix[1], p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", mix[2], p["wv"].astype(x.dtype))
    # data-dependent decay (the Finch headline): w_t = exp(-exp(w0 + lora))
    lora = jnp.einsum(
        "bsd,dr,re->bse",
        jnp.tanh(mix[3]),
        p["wa"].astype(x.dtype),
        p["wb"].astype(x.dtype),
    )
    w = jnp.exp(-jnp.exp(p["w0"] + lora.astype(jnp.float32)))  # (B,S,D) in (0,1)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", mix[4], p["wg"].astype(x.dtype)))
    b, s, d = x.shape
    h, hd = cfg.num_heads, cfg.head_dim
    shp = (b, s, h, hd)
    return (r.reshape(shp), k.reshape(shp), v.reshape(shp), w.reshape(shp), g)


def _wkv_step(state, inputs, u):
    """state (B, H, hd, hd); r,k,v,w (B, H, hd). Returns out (B, H, hd)."""
    r, k, v, w = inputs
    kv = k[..., :, None] * v[..., None, :]  # (B,H,hd,hd)
    out = jnp.einsum("bhk,bhkv->bhv", r, state + u[..., :, None] * kv)
    state = w[..., :, None] * state + kv
    return state, out


def rwkv6_apply(p, cfg: RWKV6Config, x):
    """x: (B, S, D) -> (B, S, D). Sequential lax.scan over time."""
    b, s, d = x.shape
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, w, g = _rwkv6_proj(p, cfg, x, x_prev)
    u = p["u"]
    rt = r.transpose(1, 0, 2, 3).astype(jnp.float32)
    kt = k.transpose(1, 0, 2, 3).astype(jnp.float32)
    vt = v.transpose(1, 0, 2, 3).astype(jnp.float32)
    wt = w.transpose(1, 0, 2, 3).astype(jnp.float32)
    state0 = jnp.zeros((b, cfg.num_heads, cfg.head_dim, cfg.head_dim), jnp.float32)
    _, outs = jax.lax.scan(lambda st, inp: _wkv_step(st, inp, u), state0, (rt, kt, vt, wt))
    out = outs.transpose(1, 0, 2, 3)  # (B, S, H, hd)
    out = layers.layernorm(p["ln_x"], out.astype(x.dtype))
    out = (out.reshape(b, s, d) * g.reshape(b, s, d)).astype(x.dtype)
    return jnp.einsum("bsd,de->bse", out, p["wo"].astype(x.dtype))


def rwkv6_decode(p, cfg: RWKV6Config, x, x_prev, state):
    """One-step decode. x (B, 1, D); x_prev (B, 1, D); state (B,H,hd,hd).
    Returns (out, new_x_prev, new_state)."""
    b, _, d = x.shape
    r, k, v, w, g = _rwkv6_proj(p, cfg, x, x_prev)
    state, out = _wkv_step(
        state,
        (
            r[:, 0].astype(jnp.float32),
            k[:, 0].astype(jnp.float32),
            v[:, 0].astype(jnp.float32),
            w[:, 0].astype(jnp.float32),
        ),
        p["u"],
    )
    out = layers.layernorm(p["ln_x"], out[:, None].astype(x.dtype))
    out = (out.reshape(b, 1, d) * g.reshape(b, 1, d)).astype(x.dtype)
    return jnp.einsum("bsd,de->bse", out, p["wo"].astype(x.dtype)), x, state


def rwkv6_ffn_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "mu": 0.5 * jnp.ones((2, d_model), dtype),
        "wk": layers._init_dense(k1, (d_model, d_ff), d_model, dtype),
        "wv": layers._init_dense(k2, (d_ff, d_model), d_ff, dtype),
        "wr": layers._init_dense(k3, (d_model, d_model), d_model, dtype),
    }


def rwkv6_ffn(p, x, x_prev):
    mu = p["mu"].astype(x.dtype)
    xk = x + mu[0] * (x_prev - x)
    xr = x + mu[1] * (x_prev - x)
    k = jnp.einsum("bsd,df->bsf", xk, p["wk"].astype(x.dtype))
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("bsf,fd->bsd", k, p["wv"].astype(x.dtype))
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"].astype(x.dtype)))
    return r * kv
