"""Vectorized open-addressing hash tables over numpy arrays.

This is the host-side twin of the Pallas `hash_probe` kernel: same layout
(power-of-two capacity, linear probing, -1 = empty slot), fully vectorized —
both build and probe operate on whole key batches, never one key at a time.
Composite keys are kept as column tuples and compared column-wise (no lossy
mixing), while a 64-bit mix is used only to pick the starting slot.
"""
from __future__ import annotations

import numpy as np

_FNV = np.int64(-3750763034362895579)  # 0xCBF29CE484222325 as signed
_K1 = np.int64(-7046029254386353131)  # 0x9E3779B97F4A7C15
_K2 = np.int64(-4417276706812531889)  # 0xBF58476D1CE4E5B9


def mix64(cols: list[np.ndarray]) -> np.ndarray:
    """Column-wise 64-bit mix (splitmix-style), vectorized over rows."""
    with np.errstate(over="ignore"):
        h = np.full(len(cols[0]) if cols else 0, _FNV, dtype=np.int64)
        for c in cols:
            h = (h ^ (c.astype(np.int64) * _K1)) * _K2
            h ^= h >> np.int64(29)
    return h


def _capacity(n: int) -> int:
    return max(8, 1 << int(np.ceil(np.log2(max(1, 2 * n)))))


class HashTable:
    """Maps composite integer keys -> their row index in the key arrays.

    build() expects *unique* keys (the trie build dedups first).
    probe() returns the key-row index per query, -1 on miss.
    """

    def __init__(self, key_cols: list[np.ndarray]):
        self.key_cols = [np.ascontiguousarray(c, dtype=np.int64) for c in key_cols]
        n = len(self.key_cols[0]) if self.key_cols else 0
        self.n = n
        self.cap = _capacity(n)
        self.mask = self.cap - 1
        self.slots = np.full(self.cap, -1, dtype=np.int64)
        self._build()

    def _build(self):
        n = self.n
        if n == 0:
            return
        slot = (mix64(self.key_cols) & self.mask).astype(np.int64)
        pending = np.arange(n, dtype=np.int64)
        slots = self.slots
        while pending.size:
            s = slot[pending]
            free = slots[s] == -1
            att, satt = pending[free], s[free]
            slots[satt] = att  # duplicate target slots: last write wins
            won = slots[satt] == att
            still = np.concatenate([att[~won], pending[~free]])
            slot[still] = (slot[still] + 1) & self.mask
            pending = still

    def probe(self, query_cols: list[np.ndarray]) -> np.ndarray:
        q = len(query_cols[0]) if query_cols else 0
        out = np.full(q, -1, dtype=np.int64)
        if q == 0 or self.n == 0:
            return out
        qcols = [np.asarray(c, dtype=np.int64) for c in query_cols]
        slot = (mix64(qcols) & self.mask).astype(np.int64)
        pending = np.arange(q, dtype=np.int64)
        while pending.size:
            s = slot[pending]
            occ = self.slots[s]
            filled = occ != -1
            match = filled.copy()
            if match.any():
                occ_safe = np.where(filled, occ, 0)
                for kc, qc in zip(self.key_cols, qcols):
                    match &= kc[occ_safe] == qc[pending]
            out[pending[match]] = occ[match]
            cont = filled & ~match
            pending = pending[cont]
            slot[pending] = (slot[pending] + 1) & self.mask
        return out


def group_by(key_cols: list[np.ndarray]):
    """Vectorized group-by over composite keys.

    Returns (unique_key_cols, group_of_row, order, offsets) where `order`
    permutes rows so each group is contiguous and `offsets` is the CSR
    boundary array (len = n_groups + 1). Groups are in lexicographic order.
    """
    n = len(key_cols[0]) if key_cols else 0
    if n == 0:
        return (
            [c[:0] for c in key_cols],
            np.zeros(0, np.int64),
            np.zeros(0, np.int64),
            np.zeros(1, np.int64),
        )
    order = np.lexsort(tuple(reversed([np.asarray(c) for c in key_cols])))
    sorted_cols = [np.asarray(c)[order] for c in key_cols]
    neq = np.zeros(n, dtype=bool)
    for c in sorted_cols:
        neq[1:] |= c[1:] != c[:-1]
    neq[0] = True
    starts = np.flatnonzero(neq)
    uniq = [c[starts] for c in sorted_cols]
    gid_sorted = np.cumsum(neq) - 1
    group_of_row = np.empty(n, dtype=np.int64)
    group_of_row[order] = gid_sorted
    offsets = np.concatenate([starts, [n]]).astype(np.int64)
    return uniq, group_of_row, order.astype(np.int64), offsets


def csr_expand(offsets: np.ndarray, groups: np.ndarray):
    """Expand each requested group into its member positions.

    Given CSR `offsets` and an array of group ids (one per frontier row),
    returns (row_index, member_position) pairs: `row_index[i]` is the frontier
    row and `member_position[i]` indexes into the CSR value array. Fully
    vectorized (np.repeat + cumsum trick).
    """
    if len(groups) == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    counts = offsets[groups + 1] - offsets[groups]
    total = int(counts.sum())
    row_index = np.repeat(np.arange(len(groups), dtype=np.int64), counts)
    # position within each run:
    run_starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    within = np.arange(total, dtype=np.int64) - np.repeat(run_starts, counts)
    member = np.repeat(offsets[groups], counts) + within
    return row_index, member
