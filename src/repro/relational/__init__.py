from repro.relational.relation import Relation
from repro.relational.schema import Atom, Query
from repro.relational import npkit, oracle

__all__ = ["Relation", "Atom", "Query", "npkit", "oracle"]
