"""Brute-force join oracle used by tests: pairwise nested-loop-ish natural
join over numpy (small inputs only). Bag semantics."""
from __future__ import annotations

import numpy as np

from repro.relational.relation import Relation
from repro.relational.schema import Query


def _nat_join(left_vars, left_rows, right_vars, right_rows):
    shared = [v for v in left_vars if v in right_vars]
    li = [left_vars.index(v) for v in shared]
    ri = [right_vars.index(v) for v in shared]
    rv_extra = [v for v in right_vars if v not in left_vars]
    re = [right_vars.index(v) for v in rv_extra]
    index: dict[tuple, list] = {}
    for r in right_rows:
        index.setdefault(tuple(r[i] for i in ri), []).append([r[i] for i in re])
    out_vars = list(left_vars) + rv_extra
    out = []
    for lrow in left_rows:
        for extra in index.get(tuple(lrow[i] for i in li), ()):
            out.append(list(lrow) + extra)
    return out_vars, out


def join_oracle(query: Query, relations: dict[str, Relation]) -> set | list:
    """Returns the multiset of result tuples, ordered by query.head vars,
    as a sorted list of tuples (so bag-equality is plain list equality)."""
    vars_, rows = None, None
    for atom in query.atoms:
        rel = relations[atom.alias]
        r_rows = (
            [list(t) for t in zip(*(rel.columns[v] for v in atom.vars))] if rel.num_rows else []
        )
        r_rows = [[int(x) for x in t] for t in r_rows]
        vars_, rows = (
            (list(atom.vars), r_rows)
            if vars_ is None
            else _nat_join(vars_, rows, list(atom.vars), r_rows)
        )
    idx = [vars_.index(v) for v in query.head]
    return sorted(tuple(r[i] for i in idx) for r in rows)


def result_to_sorted(result: dict[str, np.ndarray], head) -> list:
    cols = [np.asarray(result[v]) for v in head]
    return sorted(tuple(int(c[i]) for c in cols) for i in range(len(cols[0]) if cols else 0))
