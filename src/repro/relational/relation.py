"""Column-oriented relation storage (the paper stores raw data column-wise,
each column a vector, as in column-oriented databases — Sec 4.2)."""
from __future__ import annotations

import numpy as np


class Relation:
    """A named, column-oriented relation with bag semantics.

    Columns are int64 numpy arrays (join attributes are dictionary-encoded
    upstream; payload columns may be any dtype). Rows are implicit: row i is
    (col[i] for col in columns). Duplicate rows are allowed (bag semantics).
    """

    def __init__(self, name: str, columns: dict[str, np.ndarray]):
        self.name = name
        self.columns = {k: np.asarray(v) for k, v in columns.items()}
        lens = {len(v) for v in self.columns.values()}
        if len(lens) > 1:
            raise ValueError(f"ragged columns in relation {name}: {lens}")
        self.num_rows = lens.pop() if lens else 0

    @property
    def schema(self) -> tuple[str, ...]:
        return tuple(self.columns.keys())

    def cols(self, names) -> list[np.ndarray]:
        return [self.columns[n] for n in names]

    def gather(self, names, rows: np.ndarray) -> list[np.ndarray]:
        """Gather the given columns at the given row offsets."""
        return [self.columns[n][rows] for n in names]

    def select(self, mask: np.ndarray) -> "Relation":
        return Relation(self.name, {k: v[mask] for k, v in self.columns.items()})

    def rename(self, mapping: dict[str, str], name: str | None = None) -> "Relation":
        return Relation(
            name or self.name,
            {mapping.get(k, k): v for k, v in self.columns.items()},
        )

    def distinct_counts(self) -> dict[str, int]:
        return {k: len(np.unique(v)) for k, v in self.columns.items()}

    def __len__(self) -> int:
        return self.num_rows

    def __repr__(self) -> str:
        return f"Relation({self.name}, schema={self.schema}, rows={self.num_rows})"

    @staticmethod
    def from_tuples(name: str, schema, rows) -> "Relation":
        arr = np.asarray(list(rows), dtype=np.int64)
        if arr.size == 0:
            arr = arr.reshape(0, len(schema))
        return Relation(name, {v: arr[:, i] for i, v in enumerate(schema)})

    def to_tuples(self) -> list[tuple]:
        cols = list(self.columns.values())
        return [tuple(int(c[i]) for c in cols) for i in range(self.num_rows)]
