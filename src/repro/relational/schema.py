"""Query schemas: atoms, full conjunctive queries, and the query hypergraph
(Sec 2.1). Acyclicity is alpha-acyclicity decided by GYO ear removal."""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class Atom:
    """An atom R(x1,...,xk). `alias` distinguishes self-joins (the paper
    renames duplicated relation names; we carry an explicit alias)."""

    name: str
    vars: tuple[str, ...]
    alias: str = ""

    def __post_init__(self):
        if not self.alias:
            object.__setattr__(self, "alias", self.name)
        if len(set(self.vars)) != len(self.vars):
            raise ValueError(f"atom {self.name} repeats a variable: {self.vars}")

    def __str__(self):
        return f"{self.alias}({','.join(self.vars)})"


@dataclass
class Query:
    """A full conjunctive query Q(x) :- R1(x1), ..., Rm(xm)."""

    atoms: list[Atom]
    head: tuple[str, ...] = field(default_factory=tuple)

    def __post_init__(self):
        aliases = [a.alias for a in self.atoms]
        if len(set(aliases)) != len(aliases):
            raise ValueError(f"duplicate atom aliases: {aliases}")
        allv = self.variables
        if not self.head:
            self.head = tuple(allv)

    @property
    def variables(self) -> tuple[str, ...]:
        seen: dict[str, None] = {}
        for a in self.atoms:
            for v in a.vars:
                seen.setdefault(v)
        return tuple(seen)

    def atom(self, alias: str) -> Atom:
        for a in self.atoms:
            if a.alias == alias:
                return a
        raise KeyError(alias)

    def hyperedges(self) -> dict[str, frozenset[str]]:
        return {a.alias: frozenset(a.vars) for a in self.atoms}

    def is_acyclic(self) -> bool:
        """GYO reduction: repeatedly remove ears. An edge e is an ear if its
        private vertices (vars in no other edge) plus vertices covered by some
        other single edge w account for all of e."""
        edges = {k: set(v) for k, v in self.hyperedges().items()}
        changed = True
        while changed and len(edges) > 1:
            changed = False
            for k in list(edges):
                others = [v for k2, v in edges.items() if k2 != k]
                rest = set().union(*others) if others else set()
                private = edges[k] - rest
                shared = edges[k] - private
                if not shared or any(shared <= o for o in others):
                    del edges[k]
                    changed = True
                    break
        return len(edges) <= 1

    def __str__(self):
        return ", ".join(str(a) for a in self.atoms)


def triangle_query() -> Query:
    """Q_tri(x,y,z) :- R(x,y), S(y,z), T(z,x)  (Example 2.1)."""
    return Query([Atom("R", ("x", "y")), Atom("S", ("y", "z")), Atom("T", ("z", "x"))])


def clover_query() -> Query:
    """Q_clover(x,a,b,c) :- R(x,a), S(x,b), T(x,c)  (Fig. 3)."""
    return Query([Atom("R", ("x", "a")), Atom("S", ("x", "b")), Atom("T", ("x", "c"))])
