"""CI gate: lint + audit every corpus case through the production stack.

For each corpus case this builds the runner via the same acquisition
path compiled_free_join uses, runs it once (so the audited program is
the steady-state one, after any overflow growth), then:

* planlint over the stage chain + capacity plan (+ template idempotence
  for filtered cases),
* jaxpr audit over the compiled chain executor as the warm path traces it.

Any ERROR-severity diagnostic fails the process (exit 1). Warnings and
info are printed but do not fail — the severity contract of README.md.

Usage::

    PYTHONPATH=src python -m repro.analysis [--seed N] [-v]
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis.corpus import build_runner, corpus_cases
from repro.analysis.diagnostics import Report, Severity
from repro.analysis.jaxpr_audit import audit_runner
from repro.analysis.planlint import lint_chain, lint_template


def check_case(case, *, verbose: bool = False) -> Report:
    runner, rels = build_runner(case)
    rep = Report()
    # lint FIRST, on fresh planner output: the capacity-vs-AGM check is a
    # planner-regression check, and overflow growth (below) legitimately
    # raises capacities past the planned AGM record when measured needs do
    chain = runner._as_chain(runner.cap_plan)
    rep.extend(
        lint_chain(
            runner.stages,
            chain,
            filter_vars=runner.filter_vars,
            batch=runner.batch,
        )
    )
    # then run once: overflow growth settles, so the audited jaxpr is the
    # executor a warm serving stream would actually dispatch
    runner.run_relations(rels, filter_consts=case.filter_consts)
    if case.filters:
        from repro.serve.templates import canonicalize

        template, _consts = canonicalize(
            case.query, case.relations, case.filters, options=case.options
        )
        rep.extend(lint_template(template))
    rep.extend(audit_runner(runner, rels, name=f"{case.name}.jaxpr"))
    if verbose:
        print(f"  runner: {len(runner.stages)} stage(s), "
              f"{runner.compiles} compile(s), {runner.retries} retr(ies)")
    return rep


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static plan verifier + jaxpr auditor over the corpus",
    )
    ap.add_argument("--seed", type=int, default=0, help="corpus data seed")
    ap.add_argument("-v", "--verbose", action="store_true")
    ns = ap.parse_args(argv)

    failed = 0
    for case in corpus_cases(seed=ns.seed):
        rep = check_case(case, verbose=ns.verbose)
        errors = rep.errors()
        worst = "clean"
        if errors:
            worst = "ERROR"
        elif rep.warnings():
            worst = "warning"
        print(f"[{case.name}] {worst}: {len(rep.diagnostics)} diagnostic(s)")
        for d in rep:
            if d.severity >= Severity.ERROR or ns.verbose:
                print(f"  {d}")
        if errors:
            failed += 1
    if failed:
        print(f"\n{failed} corpus case(s) with error-severity findings")
        return 1
    print("\nanalysis gate: all corpus cases clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
