"""planlint — static verification of plans, schedules, and capacities.

Free Join's correctness hangs on a chain of invariants the paper states
but execution never re-checks: every probe key must be a variable some
earlier cover bound, every node needs a covering subatom for its new
variables, a stage chain must be a DAG whose output schemas match the
weighted-trie layouts built downstream, and every frontier capacity must
be positive and within the AGM bound of its prefix sub-query. The
compiled executor *assumes* all of this — a violation shows up as a
wrong answer or an XLA shape error deep inside a jit trace, attributed
to nothing.

This module checks each invariant over the host-side plan structures
(`FreeJoinPlan`, `StaticSchedule`, `CapacityPlan`/`ChainCapacityPlan`,
binary plan trees, serving templates) and reports findings as typed
diagnostics with a plan-path locator (see diagnostics.py) — never
asserts. Entry points, smallest to largest scope:

* `lint_plan`       — one FreeJoinPlan: partitioning, covers, probe
                      binding order, head binding.
* `lint_schedule`   — a StaticSchedule against its plan: entry sequence
                      and per-alias trie level layouts must match what
                      `_static_schedule` derives.
* `lint_capacities` — a CapacityPlan against its plan: arity, positive
                      capacities, compaction targets/points in range,
                      capacities within the (block-rounded) AGM cap.
* `lint_stage_dag`  — a stage chain: unique names, root last, references
                      only to earlier stages, referencing atoms matching
                      the producing stage's output schema.
* `lint_chain`      — everything above over a whole stage chain, plus
                      filter-variable coverage for kill vs mask mode.
* `lint_tree`       — a binary plan tree against its query (leaf multiset,
                      stage derivation) — the cheap admission-time check.
* `lint_template`   — serving-template canonicalization idempotence:
                      canonicalize(canonicalize(q)) == canonicalize(q).

The rule catalogue with severities lives in README.md next door; the
mutation-fuzz suite (tests/test_analysis.py) locks that every rule both
fires on its defect class and stays silent on every plan the real
planner produces.
"""
from __future__ import annotations

from repro.analysis.diagnostics import Report
from repro.core.capacity import _round_block, node_agm_bounds
from repro.core.plan import FreeJoinPlan, stage_plans
from repro.relational.schema import Atom, Query

ROOT_STAGE = "__root"


def _stage_path(stage: str) -> str:
    return f"stage[{stage}]"


# ---------------------------------------------------------------------------
# Single-plan structure
# ---------------------------------------------------------------------------


def _walk_schedule(plan: FreeJoinPlan):
    """Tolerant re-derivation of the static schedule: yields
    (k, cover, probes) like compiled._static_schedule, but degrades to the
    first non-empty subatom when a node has no cover instead of crashing —
    lint_plan must keep walking a broken plan to report everything."""
    for k, node in enumerate(plan.nodes):
        subs = [sa for sa in node if sa.vars]
        if not subs:
            continue
        covers = [sa for sa in plan.covers(k) if sa.vars and any(sa is s for s in subs)]
        cover = covers[0] if covers else subs[0]
        yield k, cover, tuple(sa for sa in subs if sa is not cover)


def lint_plan(plan: FreeJoinPlan, *, stage: str = ROOT_STAGE) -> Report:
    """Structural validity of one FreeJoinPlan (Def 3.5 + Def 3.7), plus
    the execution-order invariants the compiled path relies on: every
    probe variable bound by an earlier-or-same-node cover before it is
    used as a key, and every head variable bound somewhere."""
    rep = Report()
    sp = _stage_path(stage)
    for rule, locus, message in plan.violations():
        path = f"{sp}.atom[{locus}]" if isinstance(locus, str) else f"{sp}.node[{locus}]"
        rep.error(rule, path, message)
    # probe-binding order: the executor reads bound[v] for every probe key,
    # and bound[] is written only when a cover iterates the variable
    bound: set[str] = set()
    for k, cover, probes in _walk_schedule(plan):
        bound |= set(cover.vars)
        for j, sa in enumerate(probes):
            loose = set(sa.vars) - bound
            if loose:
                rep.error(
                    "unbound-probe-var",
                    f"{sp}.node[{k}].probe[{j}]",
                    f"probe {sa} uses variable(s) {sorted(loose)} before any "
                    f"cover binds them (bound so far: {sorted(bound)})",
                )
    plan_vars = {v for node in plan.nodes for sa in node for v in sa.vars}
    missing_head = set(plan.query.head) - plan_vars
    if missing_head:
        rep.error(
            "unbound-head-var",
            f"{sp}.head",
            f"head variable(s) {sorted(missing_head)} are never bound by the plan "
            f"(plan binds {sorted(plan_vars)})",
        )
    return rep


def lint_query(query: Query, *, path: str = "query") -> Report:
    """Query-level sanity that Query.__post_init__ does not enforce: an
    explicit head may name variables no atom binds (the executor would
    KeyError mid-trace; canonicalization would silently drop them)."""
    rep = Report()
    missing = set(query.head) - set(query.variables)
    if missing:
        rep.error(
            "unbound-head-var",
            f"{path}.head",
            f"head variable(s) {sorted(missing)} appear in no atom",
        )
    return rep


# ---------------------------------------------------------------------------
# Schedule vs plan
# ---------------------------------------------------------------------------


def lint_schedule(plan: FreeJoinPlan, schedule, *, stage: str = ROOT_STAGE) -> Report:
    """A StaticSchedule is a pure function of its plan; any drift between
    the two means the executor will probe trie levels that were never
    built (or built in a different variable order). Recompute the
    reference schedule and compare entries and per-alias level layouts."""
    from repro.core.compiled import _static_schedule  # deferred: analysis -> core only

    rep = Report()
    sp = _stage_path(stage)
    try:
        ref = _static_schedule(plan)
    except Exception as e:  # broken plan: lint_plan owns the diagnosis
        rep.error(
            "schedule-underivable",
            f"{sp}.schedule",
            f"no static schedule derivable from this plan ({e})",
        )
        return rep
    for a, lo in ref.level_ops.items():
        got = schedule.level_ops.get(a)
        if got is None:
            rep.error(
                "schedule-level-mismatch",
                f"{sp}.levels[{a}]",
                f"schedule has no level layout for alias {a!r}",
            )
        elif got.levels != lo.levels:
            rep.error(
                "schedule-level-mismatch",
                f"{sp}.levels[{a}]",
                f"trie level layout {got.levels} does not match the plan's "
                f"consumption order {lo.levels} for alias {a!r}",
            )
        elif len(got.probed) != len(got.levels):
            rep.error(
                "schedule-level-mismatch",
                f"{sp}.levels[{a}]",
                f"probed flags {got.probed} do not align with levels {got.levels}",
            )
    extra = set(schedule.level_ops) - set(ref.level_ops)
    if extra:
        rep.error(
            "schedule-level-mismatch",
            f"{sp}.levels",
            f"schedule carries layouts for unknown alias(es) {sorted(extra)}",
        )
    if tuple(schedule.entries) != tuple(ref.entries):
        for i, (got, want) in enumerate(zip(schedule.entries, ref.entries)):
            if got != want:
                rep.error(
                    "schedule-entry-mismatch",
                    f"{sp}.schedule[{i}]",
                    f"entry {got} does not match the plan-derived entry {want}",
                )
        if len(schedule.entries) != len(ref.entries):
            rep.error(
                "schedule-entry-mismatch",
                f"{sp}.schedule",
                f"schedule has {len(schedule.entries)} entries, plan derives "
                f"{len(ref.entries)}",
            )
    return rep


# ---------------------------------------------------------------------------
# Capacity plan vs plan/schedule
# ---------------------------------------------------------------------------


def lint_capacities(
    plan: FreeJoinPlan,
    cap_plan,
    *,
    stage: str = ROOT_STAGE,
    sizes: dict[str, float] | None = None,
) -> Report:
    """A CapacityPlan against its plan: one capacity per executed node,
    every capacity >= 1, compaction targets positive and strictly under
    their node capacity, compact points within the node's probe count,
    and no planned capacity above the (block-rounded) AGM bound of its
    prefix sub-query — the planner caps by AGM, so anything larger is
    either corruption or a planner regression. AGM bounds come from the
    plan's recorded `agm` tuple, or are recomputed from `sizes`
    (alias -> row count) when provided; with neither, the AGM check is
    skipped (every other check still runs).

    The AGM check applies to FRESH planner output only: overflow growth
    follows *measured* needs, which can legitimately exceed the recorded
    bound (kill-mode filtered runs record the filtered-stats AGM, but
    expansion is counted before lanes die). Lint at plan time — as
    ExecOptions.verify and the CI gate do — not after a grown run."""
    from repro.core.compiled import _static_schedule  # deferred

    rep = Report()
    sp = _stage_path(stage)
    schedule = cap_plan.schedule
    if schedule is None:
        try:
            schedule = _static_schedule(plan)
        except Exception:
            rep.error(
                "schedule-underivable",
                f"{sp}.schedule",
                "cannot align capacities: no schedule derivable from this plan",
            )
            return rep
    nsched = len(schedule.entries)
    caps = tuple(cap_plan.capacities)
    if len(caps) != nsched:
        rep.error(
            "capacity-arity",
            f"{sp}.caps",
            f"{len(caps)} capacities for {nsched} executed nodes",
        )
    compact_to = tuple(cap_plan.compact_to)
    compact_probe = tuple(cap_plan.compact_probe or (None,) * len(caps))
    block = int(getattr(cap_plan, "block", 1) or 1)
    agms = tuple(cap_plan.agm) if len(cap_plan.agm) == nsched else None
    if agms is None and sizes is not None:
        agms = tuple(node_agm_bounds(schedule.entries, dict(sizes)))
    for i, (_k, _cover, probes) in enumerate(schedule.entries):
        if i >= len(caps):
            break
        cap = caps[i]
        if cap < 1:
            rep.error(
                "capacity-not-positive",
                f"{sp}.cap[{i}]",
                f"node {i} has non-positive expansion capacity {cap}",
            )
        elif agms is not None and cap > _round_block(agms[i], block):
            rep.error(
                "capacity-over-agm",
                f"{sp}.cap[{i}]",
                f"node {i} capacity {cap} exceeds the AGM bound of its prefix "
                f"sub-query ({agms[i]:.1f}, block-rounded "
                f"{_round_block(agms[i], block)}) — a frontier can never need "
                "more lanes than the worst-case join size",
            )
        ct = compact_to[i] if i < len(compact_to) else None
        if ct is not None:
            if ct < 1:
                rep.error(
                    "compact-target-not-positive",
                    f"{sp}.compact[{i}]",
                    f"node {i} compaction target {ct} is not positive",
                )
            elif ct >= cap:
                rep.error(
                    "compact-target-oversize",
                    f"{sp}.compact[{i}]",
                    f"node {i} compacts into {ct} lanes, not smaller than its "
                    f"{cap}-lane buffer — the squeeze would enlarge the frontier",
                )
        cp = compact_probe[i] if i < len(compact_probe) else None
        if cp is not None and not (0 <= cp <= len(probes)):
            rep.error(
                "compact-point-range",
                f"{sp}.compact[{i}]",
                f"node {i} compact point {cp} outside its {len(probes)} probes",
            )
    return rep


# ---------------------------------------------------------------------------
# Stage chains (bushy plans decomposed per Sec 2.2)
# ---------------------------------------------------------------------------


def lint_stage_dag(stages) -> Report:
    """A stage chain must be a schedulable DAG: unique names, the root
    stage last, every stage-alias reference resolving to an *earlier*
    stage, and every referencing atom's variables matching the producing
    stage's output head — that head is exactly the column set of the
    weighted trie the downstream stage builds from the stage's buffer."""
    rep = Report()
    names = [name for name, _ in stages]
    heads = {name: tuple(p.query.head) for name, p in stages}
    dup = {n for n in names if names.count(n) > 1}
    for n in sorted(dup):
        rep.error("stage-name-dup", _stage_path(n), f"stage name {n!r} repeats")
    if names and names[-1] != ROOT_STAGE:
        rep.error(
            "stage-root-last",
            _stage_path(names[-1]),
            f"last stage is {names[-1]!r}, expected {ROOT_STAGE!r} "
            "(the chain's result is the last stage's output)",
        )
    defined: set[str] = set()
    for name, plan in stages:
        for atom in plan.query.atoms:
            a = atom.alias
            if a in names or a.startswith("__stage"):
                if a not in heads:
                    rep.error(
                        "stage-unknown-ref",
                        f"{_stage_path(name)}.atom[{a}]",
                        f"stage {name!r} reads {a!r}, which no stage produces",
                    )
                elif a not in defined:
                    rep.error(
                        "stage-dag-order",
                        f"{_stage_path(name)}.atom[{a}]",
                        f"stage {name!r} reads {a!r} before it is produced "
                        "(stage order must topologically sort the plan tree)",
                    )
                elif set(atom.vars) != set(heads[a]):
                    rep.error(
                        "stage-schema-mismatch",
                        f"{_stage_path(name)}.atom[{a}]",
                        f"stage {name!r} reads {a!r} with schema {atom.vars}, "
                        f"but the stage outputs {heads[a]} — the weighted trie "
                        "built from the stage buffer would miss columns",
                    )
                elif tuple(atom.vars) != heads[a]:
                    rep.warning(
                        "stage-schema-order",
                        f"{_stage_path(name)}.atom[{a}]",
                        f"stage {name!r} reads {a!r} as {atom.vars}; the stage "
                        f"outputs {heads[a]} (same columns, different order — "
                        "legal, but trie levels will consume a permuted layout)",
                    )
        defined.add(name)
    return rep


def lint_chain(
    stages,
    chain_cap_plan=None,
    *,
    sizes: dict[str, float] | None = None,
    filter_vars: tuple[str, ...] = (),
    batch: int | None = None,
) -> Report:
    """The whole pre-compile verification pass over a stage chain:
    stage-DAG shape, every stage's plan structure and schedule, every
    stage's capacities (when a ChainCapacityPlan is given), and filter-
    variable coverage. `batch` marks mask-mode (batched) filter serving;
    kill mode is the unbatched default — the coverage rule is the same
    (every filter var must be bound by some stage), but mask mode earns a
    warning when a filter var first binds in a non-root stage, because the
    terminal mult-0 fold makes every later stage per-lane and quietly
    defeats the batched pipeline sharing that mask mode exists for."""
    rep = Report()
    rep.extend(lint_stage_dag(stages))
    cps = tuple(chain_cap_plan.stages) if chain_cap_plan is not None else (None,) * len(stages)
    for (name, plan), cp in zip(stages, cps):
        rep.extend(lint_plan(plan, stage=name))
        if cp is not None:
            if cp.schedule is not None:
                rep.extend(lint_schedule(plan, cp.schedule, stage=name))
            rep.extend(lint_capacities(plan, cp, stage=name, sizes=sizes))
    # filter coverage: mirror make_chain_executor's assignment — each
    # filtered var runs its comparison in the FIRST stage that binds it
    unassigned = set(filter_vars)
    nonroot_bound: list[str] = []
    for i, (_name, plan) in enumerate(stages):
        mine = [v for v in plan.query.variables if v in unassigned]
        unassigned -= set(mine)
        if mine and i < len(stages) - 1:
            nonroot_bound.extend(mine)
    if unassigned:
        rep.error(
            "filter-unbound",
            "chain.filters",
            f"filter variable(s) {sorted(unassigned)} are bound by no stage — "
            "the executor would have no column to compare the constant against",
        )
    if batch is not None and nonroot_bound:
        rep.warning(
            "mask-filter-nonroot",
            "chain.filters",
            f"mask-mode (batched) filters on {sorted(nonroot_bound)} bind in a "
            "non-root stage: every downstream stage runs per-lane, so the "
            "batched dispatch loses most of its cross-lane sharing",
        )
    return rep


# ---------------------------------------------------------------------------
# Binary plan trees (the admission-time surface: cheap, no capacities yet)
# ---------------------------------------------------------------------------


def _tree_leaves(tree) -> list[Atom]:
    if isinstance(tree, Atom):
        return [tree]
    return _tree_leaves(tree.left) + _tree_leaves(tree.right)


def lint_tree(query: Query, tree, *, path: str = "plan_tree"):
    """A binary plan tree against its query: every query atom exactly once
    as a leaf, and the stage derivation (decompose -> binary2fj -> factor)
    must succeed. Returns (report, stages) — stages is None when the tree
    is too broken to derive them. tree=None (optimizer's choice) is
    trivially clean."""
    rep = Report()
    if tree is None:
        return rep, None
    leaves = _tree_leaves(tree)
    want = sorted(a.alias for a in query.atoms)
    got = sorted(a.alias for a in leaves)
    if got != want:
        rep.error(
            "plan-tree-atoms",
            path,
            f"plan tree leaves {got} do not match the query atoms {want} "
            "(each atom must appear exactly once)",
        )
        return rep, None
    by_alias = {a.alias: a for a in query.atoms}
    for leaf in leaves:
        qa = by_alias[leaf.alias]
        if tuple(leaf.vars) != tuple(qa.vars) or leaf.name != qa.name:
            rep.error(
                "plan-tree-atoms",
                f"{path}.leaf[{leaf.alias}]",
                f"leaf {leaf} disagrees with the query atom {qa}",
            )
    if not rep.ok:
        return rep, None
    try:
        stages = stage_plans(query, tree)
    except ValueError as e:
        rep.error("invalid-plan-tree", path, f"stage derivation failed: {e}")
        return rep, None
    return rep, stages


# ---------------------------------------------------------------------------
# Serving templates: canonicalization idempotence
# ---------------------------------------------------------------------------


def lint_template(template) -> Report:
    """Template-canonicalization idempotence: re-canonicalizing a
    template's own canonical query must be a fixed point
    (canonicalize(canonicalize(q)) == canonicalize(q)). If it is not, two
    spellings of one query can land on different template keys — each
    compiling its own executor — and the serving engine's whole
    one-compile-per-template contract silently degrades to one compile
    per spelling."""
    from repro.serve.templates import recanonicalize  # deferred: serve imports core

    rep = Report()
    try:
        again, _consts = recanonicalize(template)
    except Exception as e:
        rep.error(
            "canonicalize-not-idempotent",
            "template",
            f"re-canonicalization crashed: {e}",
        )
        return rep
    if again.key != template.key:
        rep.error(
            "canonicalize-not-idempotent",
            "template.key",
            "canonicalize(canonicalize(q)) != canonicalize(q): "
            f"{again.key} vs {template.key}",
        )
    return rep
