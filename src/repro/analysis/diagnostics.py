"""Typed diagnostics: the currency every analysis pass trades in.

A verifier that asserts is a verifier that can only be run where a crash
is acceptable — which excludes exactly the places static checking matters
most (serving admission, CI over a corpus, debug-linting thousands of
enumerated plans). Every pass in this package therefore *returns* its
findings as `Diagnostic` values collected in a `Report`; the caller
decides whether to raise (`Report.raise_errors`), reject a request, fail
a CI job, or just print.

A Diagnostic carries:

* `rule` — a stable kebab-case identifier of the invariant violated
  (e.g. ``unbound-probe-var``). Tests and CI match on rules, never on
  message text.
* `severity` — ERROR (the plan/program is wrong and must not run),
  WARNING (legal but almost certainly not what you want — e.g. a
  mask-mode filter bound in a non-root stage, which silently defeats
  batched lane sharing), INFO (observations, e.g. baked scalar consts).
* `path` — a plan-path locator pinpointing *where*: dotted segments like
  ``stage[__root].node[2].probe[1]`` or ``stage[__stage1].cap[0]``, so a
  finding over a 40-node chain is actionable without a debugger.
* `message` — the human sentence.

The rule catalogue lives in `src/repro/analysis/README.md`; adding a rule
means adding its emitter in planlint/jaxpr_audit, a mutation that trips it
in tests/test_analysis.py, and a README row.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field


class Severity(enum.IntEnum):
    """Ordered so `max(found).severity` is the report's worst finding."""

    INFO = 10
    WARNING = 20
    ERROR = 30

    def __str__(self):
        return self.name.lower()


@dataclass(frozen=True)
class Diagnostic:
    """One finding of one analysis pass (see module docstring)."""

    rule: str
    severity: Severity
    path: str
    message: str

    def __str__(self):
        return f"{self.severity}[{self.rule}] at {self.path}: {self.message}"


class PlanVerificationError(ValueError):
    """Raised (only on request — `Report.raise_errors`) when a report
    holds error-severity diagnostics. Carries the full report so callers
    that catch it (the serving engine's admission path) can attribute the
    rejection without re-running the pass."""

    def __init__(self, report: "Report"):
        self.report = report
        errs = report.errors()
        head = f"{len(errs)} plan verification error(s)"
        super().__init__(head + "".join(f"\n  {d}" for d in errs))


@dataclass
class Report:
    """An ordered collection of diagnostics with the convenience surface
    every caller wants: severity filters, merging, raise-on-error."""

    diagnostics: list[Diagnostic] = field(default_factory=list)

    def add(self, rule: str, severity: Severity, path: str, message: str) -> None:
        self.diagnostics.append(Diagnostic(rule, severity, path, message))

    def error(self, rule: str, path: str, message: str) -> None:
        self.add(rule, Severity.ERROR, path, message)

    def warning(self, rule: str, path: str, message: str) -> None:
        self.add(rule, Severity.WARNING, path, message)

    def info(self, rule: str, path: str, message: str) -> None:
        self.add(rule, Severity.INFO, path, message)

    def extend(self, other: "Report") -> "Report":
        self.diagnostics.extend(other.diagnostics)
        return self

    def errors(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity >= Severity.ERROR]

    def warnings(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == Severity.WARNING]

    def rules(self) -> set[str]:
        """The set of rules that fired (the mutation-fuzz contract: a
        corrupted plan's report must *name* the injected defect class)."""
        return {d.rule for d in self.diagnostics}

    @property
    def ok(self) -> bool:
        """True iff no error-severity findings (warnings don't fail)."""
        return not self.errors()

    def raise_errors(self) -> "Report":
        """Raise PlanVerificationError if any error-severity diagnostic is
        present; otherwise return self (chainable)."""
        if not self.ok:
            raise PlanVerificationError(self)
        return self

    def __bool__(self):  # truthiness = "found anything at all"
        return bool(self.diagnostics)

    def __iter__(self):
        return iter(self.diagnostics)

    def __str__(self):
        if not self.diagnostics:
            return "Report(clean)"
        return "\n".join(str(d) for d in self.diagnostics)
