"""Static analysis over Free Join plans and their compiled programs.

Two passes (see planlint.py and jaxpr_audit.py for the invariant
stories), one diagnostic currency (diagnostics.py), one corpus of real
planner output to keep the rules honest (corpus.py), and a CLI gate
(``python -m repro.analysis``) that CI runs over the corpus.

Entry points re-exported here are the package's public surface; the
rule catalogue and severity contract are documented in README.md.
"""
from repro.analysis.diagnostics import (
    Diagnostic,
    PlanVerificationError,
    Report,
    Severity,
)
from repro.analysis.jaxpr_audit import (
    audit_jaxpr,
    audit_runner,
    iter_bodies,
    iter_eqns,
    trace_runner,
)
from repro.analysis.planlint import (
    lint_capacities,
    lint_chain,
    lint_plan,
    lint_query,
    lint_schedule,
    lint_stage_dag,
    lint_template,
    lint_tree,
)

__all__ = [
    "Diagnostic",
    "PlanVerificationError",
    "Report",
    "Severity",
    "audit_jaxpr",
    "audit_runner",
    "iter_bodies",
    "iter_eqns",
    "trace_runner",
    "lint_capacities",
    "lint_chain",
    "lint_plan",
    "lint_query",
    "lint_schedule",
    "lint_stage_dag",
    "lint_template",
    "lint_tree",
]
