"""jaxpr audit — static checks over the *compiled program*, not the plan.

planlint proves the plan is right; it says nothing about what the plan
compiled INTO. Three regression classes live only at the jaxpr layer and
have each bitten this codebase or its ancestors:

* **device-host sync points** — a callback primitive (io_callback,
  pure_callback, debug_callback) inside the probe program serializes the
  device against the host once per dispatch. Fine in a debug harness,
  fatal in the batched serving path where one dispatch carries B tenants.
* **kernel-shape regressions** — the probe loop must lower to a
  `while`/`scan` primitive. The PR 2 bug class: a Python-level loop over
  probe rounds traced into a 32x-unrolled gather chain that type-checked,
  produced correct counts, and ran an order of magnitude slow. No test
  that checks *results* can catch it; counting loop primitives in the
  jaxpr can.
* **recompile/bake hazards** — a relation-sized buffer captured as a
  jaxpr *const* (instead of an argument) is baked into the compiled
  executable: every new dataset recompiles, and the executable bloats by
  the buffer. Scalars baked as consts are usually deliberate (capacities
  are static by design and live in the executor cache key) — those are
  reported at INFO severity as an inventory, not a finding.

`audit_jaxpr` walks a ClosedJaxpr (recursively, through pjit/while/scan
sub-jaxprs); `audit_runner` traces an AdaptiveExecutor's cached chain
executor exactly as the warm path would call it and audits the result.
Findings are typed diagnostics (see diagnostics.py) with jaxpr-path
locators like ``jaxpr.eqn[12].pjit.eqn[3]``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.diagnostics import Report

# Primitives that round-trip to the host mid-program. infeed/outfeed are
# legacy but cheap to keep on the list.
CALLBACK_PRIMITIVES = frozenset(
    {
        "io_callback",
        "pure_callback",
        "debug_callback",
        "callback",
        "infeed",
        "outfeed",
    }
)

# Primitives that prove the probe loop stayed a loop.
LOOP_PRIMITIVES = frozenset({"while", "scan", "fori_loop"})

# Gather-family primitives: the probe path's footprint in a jaxpr body.
GATHER_PRIMITIVES = frozenset({"gather", "dynamic_slice", "take"})

# More gathers than this in ONE jaxpr body (not summed over sub-jaxprs)
# means probe rounds were unrolled into straight-line code: a rolled
# probe step touches each trie level a constant number of times PER
# SCHEDULE OP, so the legitimate per-body count scales with the plan's
# op count (measured ~10-11 on the corpus), while an unrolled probe loop
# multiplies it by the round budget (32x in the PR 2 regression).
# audit_runner sizes the threshold from the runner's schedules
# (GATHERS_PER_OP * ops + slack); this constant is the flat default for
# bare audit_jaxpr calls on single-stage programs.
GATHER_UNROLL_THRESHOLD = 24
GATHERS_PER_OP = 16

# A const bigger than this many elements is a baked buffer, not a baked
# scalar. Capacity-sized scratch (iotas, pad masks) is legitimate and
# bounded by the largest planned capacity; relation-sized buffers are
# not. audit_runner raises the threshold to clear the planned capacities
# when they are larger.
CONST_ELEMS_THRESHOLD = 32768


def _sub_jaxprs(params: dict):
    """Yield (param_name, jaxpr) for every sub-jaxpr in an eqn's params —
    duck-typed so pjit (ClosedJaxpr), while (open Jaxpr pair), scan, and
    custom primitives all walk the same way."""
    for name, v in params.items():
        vs = v if isinstance(v, (tuple, list)) else (v,)
        for item in vs:
            inner = getattr(item, "jaxpr", None)
            if inner is not None and hasattr(inner, "eqns"):
                yield name, inner  # ClosedJaxpr -> its Jaxpr
            elif hasattr(item, "eqns"):
                yield name, item  # bare Jaxpr


def iter_bodies(jaxpr, path: str = "jaxpr"):
    """Yield (path, jaxpr) for the given jaxpr and every sub-jaxpr,
    depth-first. Accepts a ClosedJaxpr or a Jaxpr."""
    inner = getattr(jaxpr, "jaxpr", None)
    if inner is not None and hasattr(inner, "eqns"):
        jaxpr = inner
    yield path, jaxpr
    for i, eqn in enumerate(jaxpr.eqns):
        for pname, sub in _sub_jaxprs(eqn.params):
            prim = eqn.primitive.name
            sub_path = f"{path}.eqn[{i}].{prim}"
            if pname not in ("jaxpr", "call_jaxpr"):
                sub_path += f".{pname}"
            yield from iter_bodies(sub, sub_path)


def iter_eqns(jaxpr, path: str = "jaxpr"):
    """Yield (path, eqn) over every equation of every body."""
    for body_path, body in iter_bodies(jaxpr, path):
        for i, eqn in enumerate(body.eqns):
            yield f"{body_path}.eqn[{i}]", eqn


def audit_jaxpr(
    closed_jaxpr,
    *,
    expect_loop: bool = True,
    const_elems: int = CONST_ELEMS_THRESHOLD,
    gather_threshold: int = GATHER_UNROLL_THRESHOLD,
    name: str = "jaxpr",
) -> Report:
    """Audit one traced program. `expect_loop=True` asserts the probe loop
    survived lowering (set False for programs with nothing to probe, or
    for pallas impls whose loop lives inside the kernel). `const_elems` is
    the baked-buffer size cutoff in elements."""
    rep = Report()
    loop_count = 0
    per_body_gathers: list[tuple[str, int]] = []
    for body_path, body in iter_bodies(closed_jaxpr, name):
        gathers = 0
        for i, eqn in enumerate(body.eqns):
            prim = eqn.primitive.name
            if prim in CALLBACK_PRIMITIVES:
                rep.error(
                    "host-callback",
                    f"{body_path}.eqn[{i}]",
                    f"{prim} inside the compiled program: a device-host sync "
                    "point on every dispatch (move host work outside the "
                    "executor, or behind an explicit debug flag)",
                )
            if prim in LOOP_PRIMITIVES:
                loop_count += 1
            if prim in GATHER_PRIMITIVES:
                gathers += 1
        per_body_gathers.append((body_path, gathers))
        if gathers > gather_threshold:
            rep.error(
                "probe-loop-unrolled",
                body_path,
                f"{gathers} gather-family ops in one jaxpr body (threshold "
                f"{gather_threshold}): probe rounds appear unrolled into a "
                "straight-line gather chain instead of a while/scan loop "
                "(the PR 2 regression class)",
            )
    if expect_loop and loop_count == 0:
        rep.error(
            "probe-loop-missing",
            name,
            "no while/scan primitive anywhere in the program, but the plan "
            "has probed levels: the probe loop did not survive lowering",
        )
    consts = getattr(closed_jaxpr, "consts", ())
    n_scalar = 0
    for i, c in enumerate(consts):
        size = int(np.size(c))
        if size <= 1:
            n_scalar += 1
        elif size > const_elems:
            rep.error(
                "captured-buffer-const",
                f"{name}.const[{i}]",
                f"const #{i} has {size} elements (dtype "
                f"{getattr(c, 'dtype', type(c).__name__)}): a baked buffer — "
                "data this large must be an argument, or every new dataset "
                "recompiles the executor",
            )
    if n_scalar:
        rep.info(
            "baked-scalar-consts",
            f"{name}.consts",
            f"{n_scalar} scalar const(s) baked into the program (static "
            "capacities/budgets — deliberate; they key the executor cache)",
        )
    return rep


def _has_probes(runner) -> bool:
    return any(
        probes for sched in runner.schedules for _k, _c, probes in sched.entries
    )


def _schedule_ops(runner) -> int:
    """Total schedule ops across the chain: one per executed node (the
    cover expansion) plus one per probe — the unit the legitimate
    per-body gather count scales with."""
    return sum(
        1 + len(probes)
        for sched in runner.schedules
        for _k, _c, probes in sched.entries
    )


def trace_runner(runner, relations):
    """Trace a runner's compiled chain executor exactly as the warm path
    invokes it (registry device columns + cached base tries + zero filter
    constants) and return the ClosedJaxpr."""
    from repro.core.compiled import TRIE_CACHE, _base_aliases, device_columns

    data = {}
    for a in sorted(_base_aliases(runner.stages)):
        rel = relations[a]
        dev = device_columns(rel)
        lo = runner._alias_lops.get(a)
        data[a] = (
            TRIE_CACHE.get(rel, dev, lo, impl=runner.impl, budget=runner.budget)
            if lo is not None
            else dev
        )
    chain = runner._as_chain(runner.cap_plan)
    fn = runner._fn(chain)
    if runner.filter_vars:
        shape = (
            (runner.batch, len(runner.filter_vars))
            if runner.batch
            else (len(runner.filter_vars),)
        )
        consts = jnp.zeros(shape, jnp.int32)
        return jax.make_jaxpr(fn)(data, consts)
    return jax.make_jaxpr(fn)(data)


def audit_runner(runner, relations, *, name: str = "runner") -> Report:
    """Audit an AdaptiveExecutor's compiled program against its real
    inputs. The baked-buffer threshold clears the runner's own planned
    capacities (capacity-sized scratch is legitimate; relation-sized
    consts are the hazard) and the loop expectation is scoped to the jnp
    impl — pallas kernels carry their loop inside pallas_call."""
    chain = runner._as_chain(runner.cap_plan)
    max_cap = max(
        (c for cp in chain.stages for c in cp.capacities), default=1
    )
    const_elems = max(CONST_ELEMS_THRESHOLD, 4 * int(max_cap))
    expect_loop = runner.impl == "jnp" and _has_probes(runner)
    gather_threshold = max(
        GATHER_UNROLL_THRESHOLD, GATHERS_PER_OP * _schedule_ops(runner)
    )
    jaxpr = trace_runner(runner, relations)
    return audit_jaxpr(
        jaxpr,
        expect_loop=expect_loop,
        const_elems=const_elems,
        gather_threshold=gather_threshold,
        name=name,
    )
