"""The analysis corpus: every plan shape the system actually produces.

The mutation-fuzz suite and the CI gate need a fixed population of
*real* plans — built by the real optimizer, capacity planner, and
executor stack, over data big enough that the planner makes non-trivial
choices — to establish the zero-false-positive half of the verifier's
contract: every rule must stay silent on everything the planner emits.

Each `Case` is one (query, relations, serving knobs) combination chosen
to exercise a distinct structural regime:

* ``triangle``       — the cyclic WCOJ showcase (R(x,y) S(y,z) T(z,x)).
* ``triangle-self``  — the same shape as a self-join over one edge set.
* ``clover``         — one hub variable covering three petals (Ex. 3.6).
* ``star``           — the bench star: hub y with two satellite atoms.
* ``chain-selective``— a 4-hop chain with tiny end tables (the shape
                       where factoring and compaction actually fire).
* ``bushy``          — 5 atoms whose optimal tree is bushy: multi-stage
                       chain, stage atoms, stage-DAG checks for real.
* ``star-filtered``  — a serving template with kill-mode filters
                       (constant-parameterized executor, FilteredStats
                       capacity planning).
* ``star-batched``   — the same template vmapped over 4 lanes
                       (mask-mode filters, (B, F) constants).

`build_runner(case)` routes through `api._acquire_runner` — the SAME
acquisition path compiled_free_join and the serving engine use — so what
the corpus lints/audits is what production compiles, not a reimplementation.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.api import ExecOptions, _acquire_runner
from repro.relational.relation import Relation
from repro.relational.schema import Atom, Query


@dataclass(frozen=True)
class Case:
    """One corpus entry: a query over generated relations plus the
    serving knobs that shape the runner built from it."""

    name: str
    query: Query
    relations: dict[str, Relation] = field(hash=False)
    filters: dict[str, int] | None = field(default=None, hash=False)
    batch: int | None = None
    agg: str | None = "count"
    options: ExecOptions = ExecOptions()
    # applied to the runner's relations AFTER a first warm run, so the
    # audited executor consumes delta-merged (padded, weighted) tries
    # instead of cold builds — see build_runner
    mutate: object = field(default=None, hash=False, compare=False)

    @property
    def filter_vars(self) -> tuple[str, ...]:
        return tuple(sorted(self.filters)) if self.filters else ()

    @property
    def filter_consts(self):
        if not self.filters:
            return None
        row = np.asarray([self.filters[v] for v in self.filter_vars], np.int32)
        if self.batch is None:
            return row
        return np.tile(row, (self.batch, 1))


def _edges(rng, n: int, dom: int, a: str, b: str, name: str) -> Relation:
    return Relation(
        name,
        {a: rng.integers(0, dom, n).astype(np.int64),
         b: rng.integers(0, dom, n).astype(np.int64)},
    )


def corpus_cases(seed: int = 0) -> list[Case]:
    rng = np.random.default_rng(seed)

    cases: list[Case] = []

    # triangle: R(x,y), S(y,z), T(z,x)
    tri_rels = {
        "R": _edges(rng, 1500, 120, "x", "y", "R"),
        "S": _edges(rng, 1500, 120, "y", "z", "S"),
        "T": _edges(rng, 1500, 120, "z", "x", "T"),
    }
    tri_q = Query([Atom("R", ("x", "y")), Atom("S", ("y", "z")), Atom("T", ("z", "x"))])
    cases.append(Case("triangle", tri_q, tri_rels))

    # triangle as a self-join: one edge sample bound under three renamings
    src = rng.integers(0, 100, 1200).astype(np.int64)
    dst = rng.integers(0, 100, 1200).astype(np.int64)
    self_rels = {
        "e1": Relation("E", {"x": src, "y": dst}),
        "e2": Relation("E", {"y": src, "z": dst}),
        "e3": Relation("E", {"z": src, "x": dst}),
    }
    self_q = Query(
        [
            Atom("E", ("x", "y"), "e1"),
            Atom("E", ("y", "z"), "e2"),
            Atom("E", ("z", "x"), "e3"),
        ]
    )
    cases.append(Case("triangle-self", self_q, self_rels))

    # clover: three petals sharing hub x (the COLT showcase shape)
    clover_rels = {
        "P1": _edges(rng, 1200, 80, "x", "a", "P1"),
        "P2": _edges(rng, 1200, 80, "x", "b", "P2"),
        "P3": _edges(rng, 1200, 80, "x", "c", "P3"),
    }
    clover_q = Query(
        [Atom("P1", ("x", "a")), Atom("P2", ("x", "b")), Atom("P3", ("x", "c"))]
    )
    cases.append(Case("clover", clover_q, clover_rels))

    # star: the bench star shape
    star_rels = {
        "R": _edges(rng, 2000, 150, "x", "y", "R"),
        "S": _edges(rng, 2000, 150, "y", "a", "S"),
        "T": _edges(rng, 2000, 150, "y", "b", "T"),
    }
    star_q = Query([Atom("R", ("x", "y")), Atom("S", ("y", "a")), Atom("T", ("y", "b"))])
    cases.append(Case("star", star_q, star_rels))

    # 4-hop chain with selective ends: A and D tiny, B and C wide
    chain_rels = {
        "A": _edges(rng, 60, 40, "a", "b", "A"),
        "B": _edges(rng, 2500, 200, "b", "c", "B"),
        "C": _edges(rng, 2500, 200, "c", "d", "C"),
        "D": _edges(rng, 60, 40, "d", "e", "D"),
    }
    chain_q = Query(
        [
            Atom("A", ("a", "b")),
            Atom("B", ("b", "c")),
            Atom("C", ("c", "d")),
            Atom("D", ("d", "e")),
        ]
    )
    cases.append(Case("chain-selective", chain_q, chain_rels))

    # bushy: two independent arms meeting at the star — the optimizer's
    # DPsub enumeration picks a bushy tree here, exercising multi-stage
    # chains, stage atoms, and the stage DAG
    bushy_rels = {
        "A": _edges(rng, 900, 70, "u", "v", "A"),
        "B": _edges(rng, 900, 70, "v", "x", "B"),
        "R": _edges(rng, 1500, 110, "x", "y", "R"),
        "S": _edges(rng, 1500, 110, "y", "a", "S"),
        "T": _edges(rng, 1500, 110, "y", "b", "T"),
    }
    bushy_q = Query(
        [
            Atom("A", ("u", "v")),
            Atom("B", ("v", "x")),
            Atom("R", ("x", "y")),
            Atom("S", ("y", "a")),
            Atom("T", ("y", "b")),
        ]
    )
    cases.append(Case("bushy", bushy_q, bushy_rels))

    # the star again over delta-built tries: the runner's first (warm) run
    # builds cold, then rows are appended and tombstoned through the
    # relcache mutation API — the audited program consumes level buffers
    # produced by the sorted-run merge (padded to the capacity bucket,
    # PAD_KEY tail, multiplicity-weighted), the PR 9 storage contract
    delta_rng = np.random.default_rng(seed + 17)

    def _star_mutate(rels):
        from repro.core import relcache

        r = rels["R"]
        relcache.append(
            r,
            {v: delta_rng.integers(0, 150, 64).astype(np.int64) for v in ("x", "y")},
        )
        relcache.delete(r, np.arange(8))

    delta_rels = {
        "R": _edges(rng, 2000, 150, "x", "y", "R"),
        "S": _edges(rng, 2000, 150, "y", "a", "S"),
        "T": _edges(rng, 2000, 150, "y", "b", "T"),
    }
    cases.append(Case("star-delta", star_q, delta_rels, mutate=_star_mutate))

    # serving template, kill-mode filters (unbatched): constants are
    # runtime inputs, capacities planned for the selected slice
    cases.append(Case("star-filtered", star_q, star_rels, filters={"y": 7}))

    # the same template batched over 4 lanes: mask-mode filters, one
    # dispatch runs 4 constant vectors against shared tries
    cases.append(
        Case(
            "star-batched",
            star_q,
            star_rels,
            filters={"y": 7},
            batch=4,
        )
    )

    return cases


def build_runner(case: Case):
    """Build the case's AdaptiveExecutor through the production
    acquisition path. Returns (runner, rels): rels is the relation dict
    the runner executes over."""
    runner, rels, _cacheable, _tree = _acquire_runner(
        case.query,
        case.relations,
        None,
        agg=case.agg,
        options=case.options,
        filter_vars=case.filter_vars,
        batch=case.batch,
    )
    if case.mutate is not None:
        # warm run builds the cold tries, then the mutation goes through
        # the relcache delta API: the caller's next run (the audit pass)
        # is served merged level buffers, not a rebuild
        runner.run_relations(rels, filter_consts=case.filter_consts)
        case.mutate(rels)
    return runner, rels
