"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: O(N*Q) or scan-based implementations
with no tiling, no probe budgets, no capacity tricks. Kernel tests sweep
shapes/dtypes and assert_allclose against these.
"""
from __future__ import annotations

import jax.numpy as jnp


def hash_probe_ref(table_keys: jnp.ndarray, query_keys: jnp.ndarray) -> jnp.ndarray:
    """For each query row, the index of the matching row in table_keys
    (-1 if absent). table_keys: (N, K) unique rows; query_keys: (Q, K).
    Brute force O(N*Q*K)."""
    eq = (query_keys[:, None, :] == table_keys[None, :, :]).all(-1)  # (Q, N)
    any_hit = eq.any(axis=1)
    idx = jnp.argmax(eq, axis=1).astype(jnp.int32)
    return jnp.where(any_hit, idx, -1)


def intersect_ref(a: jnp.ndarray, b: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """a, b sorted unique 1-D int arrays. Returns (mask over a, position of
    a[i] in b or -1)."""
    eq = a[:, None] == b[None, :]
    hit = eq.any(axis=1)
    pos = jnp.argmax(eq, axis=1).astype(jnp.int32)
    return hit, jnp.where(hit, pos, -1)


def compact_ref(valid: jnp.ndarray, out_capacity: int):
    """Dense packing of the True lanes of `valid` into `out_capacity` output
    slots. Returns (src, live): src[j] = lane index of the (j+1)-th valid
    lane or -1. Gather-free exact reference (host-side nonzero)."""
    import numpy as np

    lanes = np.flatnonzero(np.asarray(valid)).astype(np.int32)
    live = jnp.int32(len(lanes))
    src = np.full(out_capacity, -1, np.int32)
    src[: min(len(lanes), out_capacity)] = lanes[:out_capacity]
    return jnp.asarray(src), live


def segmented_sort_ref(cols) -> jnp.ndarray:
    """Lexicographic sort permutation over `cols` (cols[0] major), the
    ground truth for the segmented radix sort. Host-side np.lexsort, which
    is stable — the radix kernel's per-var LSD passes must reproduce the
    exact permutation, not just the grouping."""
    import numpy as np

    host = [np.asarray(c) for c in cols]
    return jnp.asarray(np.lexsort(tuple(reversed(host))).astype(np.int32))


def csr_expand_ref(offsets: jnp.ndarray, groups: jnp.ndarray, capacity: int):
    """Expand each groups[i] into its CSR members, densely packed into a
    buffer of `capacity` slots. Returns (frontier_row, member, valid, total).
    Scan-based exact reference."""
    counts = offsets[groups + 1] - offsets[groups]
    cum = jnp.cumsum(counts)
    total = cum[-1] if counts.size else jnp.int32(0)
    starts = cum - counts
    out = jnp.arange(capacity, dtype=jnp.int32)
    # frontier row owning output slot j: last row with starts <= j
    fr = jnp.searchsorted(starts, out, side="right").astype(jnp.int32) - 1
    fr = jnp.clip(fr, 0, max(len(groups) - 1, 0))
    within = out - starts[fr]
    member = offsets[groups[fr]].astype(jnp.int32) + within
    valid = out < total
    fr = jnp.where(valid, fr, -1)
    member = jnp.where(valid, member, -1)
    return fr, member, valid, total.astype(jnp.int32)
