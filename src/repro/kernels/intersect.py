"""Pallas TPU kernel: sorted-set intersection via batched binary search.

Generic Join's leading intersection (R1.x ∩ R2.x ∩ ...) iterates the
smallest relation and probes the others. When trie keys are kept sorted
(our build is sort-based), the probe can be a binary search instead of a
hash probe — fewer memory touches for small-to-medium tables and no table
construction at all. Free Join uses it for intersection-style nodes whose
probed levels are already sorted.

The search is a fixed-depth (ceil(log2(N))) loop of masked midpoint updates:
static control flow, fully vectorized across a QBLK tile of query lanes.
"""
from __future__ import annotations

import functools

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QBLK = 1024


def _bsearch_kernel(b_ref, a_ref, mask_ref, pos_ref, *, n: int, steps: int):
    a = a_ref[...]  # (QBLK,) queries
    b = b_ref[...]  # (n,) sorted table
    lo = jnp.zeros(a.shape, dtype=jnp.int32)
    hi = jnp.full(a.shape, n, dtype=jnp.int32)  # search in [lo, hi)
    for _ in range(steps):
        mid = (lo + hi) // 2
        midv = b[jnp.clip(mid, 0, n - 1)]
        go_right = jnp.logical_and(midv < a, mid < hi)
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(go_right, hi, jnp.maximum(mid, lo))
    found = jnp.logical_and(lo < n, b[jnp.clip(lo, 0, n - 1)] == a)
    mask_ref[...] = found
    pos_ref[...] = jnp.where(found, lo, -1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def intersect_pallas(a: jnp.ndarray, b: jnp.ndarray, *, interpret: bool = True):
    """a: (Q,) int32 queries (Q % QBLK == 0); b: (N,) sorted int32, N >= 1.
    Returns (mask, pos): membership of each a[i] in b and its index."""
    n = int(b.shape[0])
    steps = max(1, math.ceil(math.log2(n + 1)))
    q = a.shape[0]
    kernel = functools.partial(_bsearch_kernel, n=n, steps=steps)
    return pl.pallas_call(
        kernel,
        grid=(q // QBLK,),
        in_specs=[
            pl.BlockSpec(b.shape, lambda i: (0,)),
            pl.BlockSpec((QBLK,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((QBLK,), lambda i: (i,)),
            pl.BlockSpec((QBLK,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q,), jnp.bool_),
            jax.ShapeDtypeStruct((q,), jnp.int32),
        ],
        interpret=interpret,
    )(b, a)
