"""Pallas TPU kernel: segmented radix sort for lazy trie construction.

The compiled Free Join trie build needs rows grouped hierarchically by the
plan's level vars. A full-width comparison sort (jnp.lexsort over every
level var at once) pays N log N comparisons per var and re-sorts vars that
earlier levels already grouped; Worst-Case Optimal Radix Triejoin
(arXiv 1912.12747) observes that radix partitioning level-by-level is the
right primitive: at level d the rows are already contiguous within their
depth-(d-1) groups, so the level's var only has to be rank-ordered *inside
each parent segment* — a stable LSD counting sort over small digits whose
passes scale with the key width of that one var, not with the whole key
tuple.

One pass (digit width RBITS, radix R = 2**RBITS) over the current
permutation works on three precomputed arrays:

  digit[i]   the i-th row's current digit
  csum[i,r]  inclusive count of digit r among rows 0..i (a (N,R) cumsum)
  seg[i]     the row's parent segment id (non-decreasing: segments are
             contiguous runs of the current order)

and sends row i to
  dst[i] = seg_start + offset_of_digit_within_segment + rank_within(seg,digit)
— a permutation that never crosses segment boundaries, so the segment ids
survive every pass unchanged and stability gives the lexicographic order.

Like kernels/compact.py, the scatter is re-expressed as a gather so each
output slot is written exactly once: slot j knows its digit k_j and its
target rank t_j (precomputed outside the kernel from the per-segment digit
histograms), and its source row is the leftmost i with csum[i, k_j] >= t_j —
one binary search per slot, the same VPU profile as csr_expand. The jnp
variant keeps the scatter formulation (XLA fuses it); the Pallas kernel is
the gather.

Keys must be non-negative (join keys are dictionary-encoded int32 >= 0);
negative sentinel keys (SPMD pad rows, PAD_KEY stage pads) stay on the
lexsort path — see compiled.build_trie.

On CPU the jnp variant runs within ~2x of XLA's comparison lexsort (the
(N, R) histogram cumsums have no vector unit to feed); the design targets
the TPU regime, where XLA's variadic sort is the known weak spot and every
pass here is cumsum + per-row gather + one scatter — native VPU work. In
the cached build-once architecture the sort runs once per relation either
way, so cold-build cost is amortized to zero across calls.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

RBITS = 4
RADIX = 1 << RBITS
SBLK = 1024


def _rank_kernel(csum_ref, kd_ref, kt_ref, src_ref, *, n: int, steps: int):
    """src[j] = leftmost i with csum[i, kd[j]] >= kt[j] (csum columns are
    non-decreasing). One binary search per output slot."""
    csum = csum_ref[...]  # (n, R)
    kd = kd_ref[...]
    kt = kt_ref[...]
    lo = jnp.zeros(kd.shape, dtype=jnp.int32)
    hi = jnp.full(kd.shape, n, dtype=jnp.int32)
    for _ in range(steps):
        mid = (lo + hi) // 2
        midv = csum[jnp.clip(mid, 0, n - 1), kd]
        open_ = lo < hi
        hi = jnp.where(open_ & (midv >= kt), mid, hi)
        lo = jnp.where(open_ & (midv < kt), mid + 1, lo)
    src_ref[...] = jnp.clip(lo, 0, n - 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def radix_rank_pallas(
    csum: jnp.ndarray,
    kd: jnp.ndarray,
    kt: jnp.ndarray,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """csum: (N, R) int32 inclusive per-digit prefix counts; kd/kt: (N,)
    int32 digit and target rank per output slot (N % SBLK == 0 is padded
    here). Returns src: (N,) int32 source position of each output slot."""
    n = int(csum.shape[0])
    cap = n + ((-n) % SBLK)
    if cap != n:
        kd = jnp.pad(kd, (0, cap - n))
        kt = jnp.pad(kt, (0, cap - n))
    steps = max(1, math.ceil(math.log2(n + 1)))
    kernel = functools.partial(_rank_kernel, n=n, steps=steps)
    src = pl.pallas_call(
        kernel,
        grid=(cap // SBLK,),
        in_specs=[
            pl.BlockSpec(csum.shape, lambda i: (0, 0)),
            pl.BlockSpec((SBLK,), lambda i: (i,)),
            pl.BlockSpec((SBLK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((SBLK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((cap,), jnp.int32),
        interpret=interpret,
    )(csum, kd, kt)
    return src[:n]


def _seg_starts(seg: jnp.ndarray) -> jnp.ndarray:
    """Per-row start position of the row's (contiguous) segment."""
    n = seg.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    first = jnp.zeros(n, dtype=bool).at[0].set(True)
    first = first.at[1:].set(seg[1:] != seg[:-1])
    # running max of the last segment-start position
    return jax.lax.cummax(jnp.where(first, idx, 0))


def _radix_pass(perm, starts, seg_last, digit: jnp.ndarray, impl: str):
    """One stable counting-sort pass of `perm` by `digit` within contiguous
    segments. `starts`/`seg_last` give each row's segment start/end position
    (invariant across the passes of one var — computed once by the caller).
    Returns the new permutation of positions (segments are preserved)."""
    n = perm.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    onehot = (digit[:, None] == jnp.arange(RADIX, dtype=jnp.int32)[None, :]).astype(jnp.int32)
    csum = jnp.cumsum(onehot, axis=0)  # (N, R) inclusive per-digit counts
    pcs = jnp.cumsum(csum, axis=1)  # (N, R): rows <= j with digit <= r
    start1 = jnp.clip(starts - 1, 0, n - 1)
    at_start = starts > 0

    def upto(tbl, col):  # tbl[., col] restricted to the row's segment
        return tbl[seg_last, col] - jnp.where(at_start, tbl[start1, col], 0)

    if impl == "jnp":
        # every lookup is a per-row scalar gather — no (N, R) gathers
        within = csum[idx, digit] - jnp.where(at_start, csum[start1, digit], 0) - 1
        off = jnp.where(digit > 0, upto(pcs, jnp.maximum(digit - 1, 0)), 0)
        dst = starts + off + within
        src = jnp.zeros(n, jnp.int32).at[dst].set(idx)
        return perm[src]
    # gather formulation (the Pallas kernel): slot j's digit and target rank
    local = idx - starts  # position within the segment
    seg_pcs = pcs[seg_last] - jnp.where(at_start[:, None], pcs[start1], 0)  # (N, R)
    kd = jnp.sum((seg_pcs <= local[:, None]).astype(jnp.int32), axis=1).astype(jnp.int32)
    kd = jnp.clip(kd, 0, RADIX - 1)
    off = jnp.where(kd > 0, upto(pcs, jnp.maximum(kd - 1, 0)), 0)
    base = jnp.where(at_start, csum[start1, kd], 0)  # digit-kd rows before the segment
    kt = base + (local - off) + 1
    src = radix_rank_pallas(csum, kd, kt, interpret=impl == "pallas_interpret")
    return perm[src]


def _refine_segments(seg: jnp.ndarray, sorted_key: jnp.ndarray) -> jnp.ndarray:
    """New segment ids after a var is fully sorted: split each segment at
    every value change of the (now sorted-within-segment) key."""
    flag = jnp.zeros(seg.shape[0], dtype=bool).at[0].set(True)
    flag = flag.at[1:].set((seg[1:] != seg[:-1]) | (sorted_key[1:] != sorted_key[:-1]))
    return (jnp.cumsum(flag.astype(jnp.int32)) - 1).astype(jnp.int32)


def segmented_sort(
    cols: list[jnp.ndarray],
    key_bits: tuple[int, ...],
    impl: str = "jnp",
    init_order: jnp.ndarray | None = None,
    presorted: int = 0,
) -> jnp.ndarray:
    """Row permutation sorting `cols` lexicographically (cols[0] major), via
    per-var LSD radix passes inside the segments induced by earlier vars.

    key_bits[i] must cover cols[i]'s value range (values in [0, 2**bits));
    pass count per var is ceil(key_bits[i] / RBITS) — static, so the whole
    sort lowers under jit. `init_order` with `presorted=k` starts from a
    permutation already sorted by the first k cols (a shared prefix order
    from the trie cache): those vars pay only the segment refinement, never
    a sorting pass."""
    assert len(cols) == len(key_bits) and cols, "one key width per column"
    n = int(cols[0].shape[0])
    perm = (
        jnp.arange(n, dtype=jnp.int32)
        if init_order is None
        else init_order.astype(jnp.int32)
    )
    assert 0 <= presorted <= len(cols)
    assert presorted == 0 or init_order is not None, "presorted needs init_order"
    seg = jnp.zeros(n, jnp.int32)
    for ci, (col, bits) in enumerate(zip(cols, key_bits)):
        col = col.astype(jnp.int32)
        if ci >= presorted:
            starts = _seg_starts(seg)
            seg_last = (n - 1) - _seg_starts(seg[::-1])[::-1]  # last position
            for shift in range(0, max(1, int(bits)), RBITS):
                digit = (col[perm] >> shift) & (RADIX - 1)
                perm = _radix_pass(perm, starts, seg_last, digit, impl)
        seg = _refine_segments(seg, col[perm])
    return perm


def lex_searchsorted(
    sorted_cols: list[jnp.ndarray],
    query_cols: list[jnp.ndarray],
) -> jnp.ndarray:
    """Per-query insertion rank (side="left") of each query tuple into the
    lexicographically sorted rows of `sorted_cols` (cols[0] major).

    The merge half of the delta trie build: the delta's rows are sorted
    among themselves by `segmented_sort`, then this locates each one's slot
    in the cached sorted run — the splice positions of a sorted-run merge
    without a full re-sort. Same fixed-step binary-search shape as
    `_rank_kernel`: ceil(log2(N+1)) gather rounds, each lane masked once
    its bracket closes, so the whole search lowers under jit with static
    iteration count. Lexicographic "row < query" is folded from the least
    significant column backward: a < b at column d iff
    (a_d < b_d) | (a_d == b_d & a_{<d-suffix} < b-suffix).
    """
    assert sorted_cols and len(sorted_cols) == len(query_cols)
    n = int(sorted_cols[0].shape[0])
    q = query_cols[0].shape[0]
    if n == 0:
        return jnp.zeros(q, dtype=jnp.int32)

    def row_lt_query(pos):  # (Q,) bool: sorted row `pos[j]` < query j ?
        lt = jnp.zeros(pos.shape, dtype=bool)
        for sc, qc in zip(reversed(sorted_cols), reversed(query_cols)):
            a = sc.astype(jnp.int32)[pos]
            b = qc.astype(jnp.int32)
            lt = (a < b) | ((a == b) & lt)
        return lt

    lo = jnp.zeros(q, dtype=jnp.int32)
    hi = jnp.full(q, n, dtype=jnp.int32)
    for _ in range(max(1, math.ceil(math.log2(n + 1)))):
        mid = (lo + hi) // 2
        lt = row_lt_query(jnp.clip(mid, 0, n - 1))
        open_ = lo < hi
        lo = jnp.where(open_ & lt, mid + 1, lo)
        hi = jnp.where(open_ & ~lt, mid, hi)
    return lo
