"""Jit'd wrappers around the Pallas kernels, plus the table build.

Three implementations per op, selected by `impl`:
  "jnp"               pure-jnp vectorized path (default on CPU; identical
                      math to the kernel, XLA-fused)
  "pallas_interpret"  the Pallas kernel body executed in interpret mode
                      (CPU correctness validation of the TPU kernel)
  "pallas"            compiled Pallas (TPU target)

The hash-table *build* is sort-based and stays in jnp by design: slot
assignment after sorting by home slot is `slot_i = i + cummax(h_i - i)`
(an associative scan), so XLA already emits the optimal sort + scan; there
is no tiling decision for a kernel to make. The probe is where the kernel
earns its keep (many probes per build, VPU-bound).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.compact import CBLK, compact_pallas
from repro.kernels.csr_expand import OBLK, csr_expand_pallas
from repro.kernels.hash_probe import PROBE_BUDGET, QBLK, hash_probe_pallas, mix32
from repro.kernels.intersect import intersect_pallas
from repro.kernels.radix_sort import (  # noqa: F401  (impl trio inside)
    lex_searchsorted,
    segmented_sort,
)


class Table(NamedTuple):
    slots: jnp.ndarray  # (cap + PROBE_BUDGET,) int32 row index or -1
    keys: jnp.ndarray  # (N, K) int32 key rows
    max_disp: jnp.ndarray  # () int32: max probe distance used at build


def _next_pow2(n: int) -> int:
    return max(8, 1 << (max(1, 2 * n) - 1).bit_length())


@functools.partial(jax.jit, static_argnames=("cap", "budget"))
def _build(keys: jnp.ndarray, cap: int, budget: int = PROBE_BUDGET) -> Table:
    n = keys.shape[0]
    h = mix32(keys) & (cap - 1)
    order = jnp.argsort(h).astype(jnp.int32)
    hs = h[order]
    disp = jax.lax.cummax(hs - jnp.arange(n, dtype=jnp.int32))
    slot = jnp.arange(n, dtype=jnp.int32) + disp
    max_disp = (slot - hs).max(initial=0)
    slots = jnp.full(cap + budget, -1, dtype=jnp.int32)
    slots = slots.at[slot].set(order, mode="drop")
    return Table(slots=slots, keys=keys, max_disp=max_disp)


def build_table(keys: jnp.ndarray, budget: int = PROBE_BUDGET) -> Table:
    """keys: (N, K) int32, rows unique. Linear probing, load factor <= 0.5,
    no wraparound (tail margin = `budget`). max_disp >= budget would mean an
    overflow — astronomically unlikely at <=0.5 load; checked by callers in
    tests via table.max_disp. Smaller budgets shrink the unrolled probe loop
    (§Perf J1) at the cost of a tighter displacement margin."""
    if keys.ndim != 2:
        raise ValueError("keys must be (N, K)")
    return _build(keys, _next_pow2(keys.shape[0]), budget)


@functools.partial(jax.jit, static_argnames=("budget",))
def _probe_jnp(slots, keys, queries, budget: int):
    # rolled as a while_loop, not a Python loop: XLA's CPU pipeline hits
    # multi-minute compiles on the 32x-unrolled gather chain at some small
    # shapes (run the tier-1 suite at 17 keys / 64 queries to reproduce);
    # the rolled loop compiles in milliseconds. Early exit: at load factor
    # <= 0.5 almost every lane resolves within the first couple of probe
    # rounds, so the loop stops as soon as *all* lanes are done instead of
    # always paying `budget` gather rounds — same results, identical math.
    cap = slots.shape[0] - budget
    h = mix32(queries) & (cap - 1)
    nkeys = keys.shape[0]

    def cond(state):
        p, _res, done = state
        return (p < budget) & ~done.all()

    def body(state):
        p, res, done = state
        cand = slots[h + p]
        is_empty = cand < 0
        krow = keys[jnp.clip(cand, 0, nkeys - 1)]
        match = (~is_empty) & (krow == queries).all(axis=-1)
        hit = match & ~done
        return p + 1, jnp.where(hit, cand, res), done | hit | is_empty

    init = (
        jnp.int32(0),
        jnp.full(h.shape, -1, dtype=jnp.int32),
        jnp.zeros(h.shape, dtype=bool),
    )
    _, res, _ = jax.lax.while_loop(cond, body, init)
    return res


def _pad_rows(x: jnp.ndarray, mult: int, fill) -> tuple[jnp.ndarray, int]:
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        width = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
        x = jnp.pad(x, width, constant_values=fill)
    return x, n


def probe(table: Table, queries: jnp.ndarray, impl: str = "jnp") -> jnp.ndarray:
    """queries: (Q, K) int32 -> (Q,) int32 row index in table.keys or -1."""
    if table.keys.shape[0] == 0 or queries.shape[0] == 0:
        return jnp.full(queries.shape[0], -1, dtype=jnp.int32)
    if impl == "jnp":
        budget = table.slots.shape[0] - _next_pow2(table.keys.shape[0])
        return _probe_jnp(table.slots, table.keys, queries, budget)
    q, n = _pad_rows(queries, QBLK, 0)
    out = hash_probe_pallas(table.slots, table.keys, q, interpret=impl == "pallas_interpret")
    return out[:n]


def intersect_sorted(a: jnp.ndarray, b: jnp.ndarray, impl: str = "jnp"):
    """a: (Q,) queries; b: (N,) sorted unique. Returns (mask, pos)."""
    if b.shape[0] == 0 or a.shape[0] == 0:
        return jnp.zeros(a.shape[0], bool), jnp.full(a.shape[0], -1, jnp.int32)
    if impl == "jnp":
        pos = jnp.searchsorted(b, a).astype(jnp.int32)
        mask = (pos < b.shape[0]) & (b[jnp.clip(pos, 0, b.shape[0] - 1)] == a)
        return mask, jnp.where(mask, pos, -1)
    ap, n = _pad_rows(a, QBLK, 0)
    mask, pos = intersect_pallas(ap, b, interpret=impl == "pallas_interpret")
    return mask[:n], pos[:n]


def expand_counted(
    base: jnp.ndarray,
    counts: jnp.ndarray,
    capacity: int,
    impl: str = "jnp",
):
    """Variable-fanout expansion: frontier row i contributes `counts[i]`
    outputs, the j-th reading position base[i] + j. Returns
    (fr, member, valid, total) with static `capacity`. Rows with count 0
    (e.g. invalid frontier slots) contribute nothing."""
    counts = counts.astype(jnp.int32)
    cum = jnp.cumsum(counts)
    total = (cum[-1] if counts.shape[0] else jnp.int32(0)).astype(jnp.int32)
    starts = (cum - counts).astype(jnp.int32)
    base = base.astype(jnp.int32)
    if impl == "jnp":
        out = jnp.arange(capacity, dtype=jnp.int32)
        fr = jnp.searchsorted(starts, out, side="right").astype(jnp.int32) - 1
        fr = jnp.clip(fr, 0, max(counts.shape[0] - 1, 0))
        member = base[fr] + (out - starts[fr])
        valid = out < total
        return jnp.where(valid, fr, -1), jnp.where(valid, member, -1), valid, total
    cap = capacity + ((-capacity) % OBLK)
    fr, member = csr_expand_pallas(
        starts, base, total[None], capacity=cap, interpret=impl == "pallas_interpret"
    )
    valid = jnp.arange(cap, dtype=jnp.int32) < total
    return fr[:capacity], member[:capacity], valid[:capacity], total


def compact_indices(
    valid: jnp.ndarray,
    out_capacity: int,
    impl: str = "jnp",
):
    """Frontier compaction: squeeze the lanes where `valid` is True densely
    into the front of a buffer of `out_capacity` slots. Returns (src, live):
    src[j] is the source lane of output slot j (-1 beyond the live count),
    live is the number of valid lanes. Overflow iff live > out_capacity —
    detected by the caller, never silent (mirrors expand_counted)."""
    n = valid.shape[0]
    if n == 0:
        return jnp.full(out_capacity, -1, jnp.int32), jnp.int32(0)
    csum = jnp.cumsum(valid.astype(jnp.int32))
    live = csum[-1].astype(jnp.int32)
    if impl == "jnp":
        out = jnp.arange(out_capacity, dtype=jnp.int32)
        src = jnp.searchsorted(csum, out + 1, side="left").astype(jnp.int32)
        src = jnp.clip(src, 0, n - 1)
        return jnp.where(out < live, src, -1), live
    cap = out_capacity + ((-out_capacity) % CBLK)
    src = compact_pallas(csum, live[None], capacity=cap, interpret=impl == "pallas_interpret")
    return src[:out_capacity], live


def csr_expand_capped(
    offsets: jnp.ndarray,
    groups: jnp.ndarray,
    capacity: int,
    impl: str = "jnp",
):
    """Expand CSR members of each groups[i] into a `capacity` buffer.
    Returns (fr, member, valid, total). offsets: (G+1,) int32; groups: (F,).
    """
    if groups.shape[0] == 0:
        z = jnp.full(capacity, -1, jnp.int32)
        return z, z, jnp.zeros(capacity, bool), jnp.int32(0)
    counts = (offsets[groups + 1] - offsets[groups]).astype(jnp.int32)
    cum = jnp.cumsum(counts)
    total = cum[-1].astype(jnp.int32)
    starts = (cum - counts).astype(jnp.int32)
    base = offsets[groups].astype(jnp.int32)
    if impl == "jnp":
        out = jnp.arange(capacity, dtype=jnp.int32)
        fr = jnp.searchsorted(starts, out, side="right").astype(jnp.int32) - 1
        fr = jnp.clip(fr, 0, groups.shape[0] - 1)
        member = base[fr] + (out - starts[fr])
        valid = out < total
        return (
            jnp.where(valid, fr, -1),
            jnp.where(valid, member, -1),
            valid,
            total,
        )
    cap = capacity + ((-capacity) % OBLK)
    fr, member = csr_expand_pallas(
        starts, base, total[None], capacity=cap, interpret=impl == "pallas_interpret"
    )
    valid = jnp.arange(cap, dtype=jnp.int32) < total
    return fr[:capacity], member[:capacity], valid[:capacity], total
