"""Pallas TPU kernel: capacity-bounded CSR expansion.

Free Join's cover iteration expands every frontier row into the members of
its trie sub-group (variable fan-out). On static-shape hardware the output
is a fixed-capacity buffer; each output slot finds its source frontier row
by binary search over the running prefix sum of fan-outs, then computes its
member offset. One gather-heavy, matmul-free pass — the write side of the
same VPU profile as hash_probe.

Inputs are precomputed outside the kernel: `starts` (exclusive prefix sum of
per-frontier-row counts) and `base` (each row's CSR segment start). The
kernel fills `capacity` output slots; slots >= total are -1.
"""
from __future__ import annotations

import functools

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

OBLK = 1024


def _expand_kernel(
    starts_ref, base_ref, total_ref, fr_ref, member_ref, *, f: int, steps: int, oblk: int
):
    i = pl.program_id(0)
    j = jax.lax.broadcasted_iota(jnp.int32, (oblk,), 0) + i * oblk
    starts = starts_ref[...]
    total = total_ref[0]
    # rightmost row with starts[row] <= j  (upper_bound - 1)
    lo = jnp.zeros(j.shape, dtype=jnp.int32)
    hi = jnp.full(j.shape, f, dtype=jnp.int32)
    for _ in range(steps):
        mid = (lo + hi) // 2
        midv = starts[jnp.clip(mid, 0, f - 1)]
        go_right = jnp.logical_and(midv <= j, mid < hi)
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(go_right, hi, jnp.maximum(mid, lo))
    fr = jnp.clip(lo - 1, 0, f - 1)
    valid = j < total
    member = base_ref[...][fr] + (j - starts[fr])
    fr_ref[...] = jnp.where(valid, fr, -1)
    member_ref[...] = jnp.where(valid, member, -1)


@functools.partial(jax.jit, static_argnames=("capacity", "interpret"))
def csr_expand_pallas(
    starts: jnp.ndarray,
    base: jnp.ndarray,
    total: jnp.ndarray,
    *,
    capacity: int,
    interpret: bool = True,
):
    """starts/base: (F,) int32, F >= 1; total: (1,) int32.
    Returns (fr, member): each (capacity,) int32, -1 beyond total."""
    f = int(starts.shape[0])
    steps = max(1, math.ceil(math.log2(f + 1)))
    assert capacity % OBLK == 0
    kernel = functools.partial(_expand_kernel, f=f, steps=steps, oblk=OBLK)
    return pl.pallas_call(
        kernel,
        grid=(capacity // OBLK,),
        in_specs=[
            pl.BlockSpec(starts.shape, lambda i: (0,)),
            pl.BlockSpec(base.shape, lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((OBLK,), lambda i: (i,)),
            pl.BlockSpec((OBLK,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((capacity,), jnp.int32),
            jax.ShapeDtypeStruct((capacity,), jnp.int32),
        ],
        interpret=interpret,
    )(starts, base, total)
