"""Pallas TPU kernel: frontier compaction (prefix-sum scatter).

The compiled Free Join frontier is a fixed-capacity buffer with a valid
mask; probe misses kill lanes in place. Every dead lane is still carried
through all later expansions (cumsum, binary search, gathers all scale with
the *buffer* length, not the live count). When the live fraction drops, the
adaptive runner squeezes the frontier: output slot j is filled from the
(j+1)-th valid lane, so the live lanes land densely at the front of a
smaller buffer and every later node runs at the compacted capacity.

The scatter is expressed as a gather so each output slot is written exactly
once (no atomics): with `csum = cumsum(valid)` (inclusive, precomputed
outside the kernel like csr_expand's `starts`), the source lane of output
slot j is the leftmost i with csum[i] >= j+1 — one binary search per slot,
the same VPU profile as csr_expand.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CBLK = 1024


def _compact_kernel(csum_ref, live_ref, src_ref, *, n: int, steps: int, cblk: int):
    i = pl.program_id(0)
    j = jax.lax.broadcasted_iota(jnp.int32, (cblk,), 0) + i * cblk
    csum = csum_ref[...]
    live = live_ref[0]
    target = j + 1
    # leftmost i with csum[i] >= target (csum is non-decreasing)
    lo = jnp.zeros(j.shape, dtype=jnp.int32)
    hi = jnp.full(j.shape, n, dtype=jnp.int32)
    for _ in range(steps):
        mid = (lo + hi) // 2
        midv = csum[jnp.clip(mid, 0, n - 1)]
        open_ = lo < hi
        hi = jnp.where(open_ & (midv >= target), mid, hi)
        lo = jnp.where(open_ & (midv < target), mid + 1, lo)
    src_ref[...] = jnp.where(j < live, jnp.clip(lo, 0, n - 1), -1)


@functools.partial(jax.jit, static_argnames=("capacity", "interpret"))
def compact_pallas(
    csum: jnp.ndarray,
    live: jnp.ndarray,
    *,
    capacity: int,
    interpret: bool = True,
) -> jnp.ndarray:
    """csum: (N,) int32 inclusive prefix sum of the valid mask, N >= 1;
    live: (1,) int32 == csum[-1]. Returns src: (capacity,) int32 source lane
    of each output slot, -1 beyond live."""
    n = int(csum.shape[0])
    steps = max(1, math.ceil(math.log2(n + 1)))
    assert capacity % CBLK == 0
    kernel = functools.partial(_compact_kernel, n=n, steps=steps, cblk=CBLK)
    return pl.pallas_call(
        kernel,
        grid=(capacity // CBLK,),
        in_specs=[
            pl.BlockSpec(csum.shape, lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((CBLK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((capacity,), jnp.int32),
        interpret=interpret,
    )(csum, live)
