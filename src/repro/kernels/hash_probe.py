"""Pallas TPU kernel: batched open-addressing hash-table probe.

This is the hot loop of Free Join: every plan node probes each non-cover
relation's trie level with the whole frontier as one batch (Sec 4.3 taken to
its vector-hardware limit). The table is built once (sort + associative-scan
slot assignment, see ops.build_table) and probed many times, so the probe is
the kernel.

Layout: `slots` is a flat int32 array of length cap + PROBE_BUDGET; slots[s]
holds a row index into `table_keys` (or -1 = empty). A query key with home
slot h = mix(key) & (cap-1) lives within PROBE_BUDGET slots of h (linear
probing, no wrap — the tail margin absorbs the last cluster). The kernel
does PROBE_BUDGET unrolled gather+compare steps per query tile; each step is
a VMEM vector gather plus K int32 compares, so the whole probe is
memory-regular and MXU-free — ideal VPU work.

Tiling: queries are tiled (QBLK, K) in VMEM; the table (slots + key rows)
is resident in VMEM per block. For tables beyond VMEM the caller shards the
table (hash-partitioned) across the mesh instead — see core/distributed.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PROBE_BUDGET = 32
QBLK = 1024

_C1 = -1640531527  # 0x9E3779B9: Knuth multiplicative (int32 wrap)
_C2 = -862048943  # 0xCC9E2D51: murmur3 c1


def mix32(cols2d: jnp.ndarray) -> jnp.ndarray:
    """Mix (N, K) int32 key rows into int32 hashes (rows -> lanes).
    Constants are Python ints so the function is safe inside Pallas
    kernel bodies (no captured device arrays)."""
    h = jnp.full(cols2d.shape[:-1], 374761393, dtype=jnp.int32)
    k = cols2d.shape[-1]
    for i in range(k):
        c = cols2d[..., i]
        h = (h ^ (c * _C2)) * _C1
        h = h ^ (jax.lax.shift_right_logical(h, 15))
    return h


def _probe_kernel(slots_ref, tkeys_ref, q_ref, out_ref, *, cap: int, budget: int):
    q = q_ref[...]  # (QBLK, K)
    h = mix32(q) & (cap - 1)  # (QBLK,)
    slots = slots_ref[...]
    tkeys = tkeys_ref[...]
    nkeys = tkeys.shape[0]

    # rolled probe loop (fori_loop, not Python unrolling): the unrolled
    # gather chain triggers multi-minute XLA compiles at some table shapes
    # (seen in interpret mode on CPU); trip count is still the static budget
    def step(p, carry):
        res, done = carry
        cand = slots[h + p]  # VMEM vector gather
        is_empty = cand < 0
        krow = tkeys[jnp.clip(cand, 0, nkeys - 1)]  # (QBLK, K)
        match = jnp.logical_and(~is_empty, (krow == q).all(axis=-1))
        hit = jnp.logical_and(match, ~done)
        return jnp.where(hit, cand, res), done | hit | is_empty

    res = jnp.full(h.shape, -1, dtype=jnp.int32)
    done = jnp.zeros(h.shape, dtype=jnp.bool_)
    res, done = jax.lax.fori_loop(0, budget, step, (res, done))
    out_ref[...] = res


@functools.partial(jax.jit, static_argnames=("interpret",))
def hash_probe_pallas(
    slots: jnp.ndarray,
    table_keys: jnp.ndarray,
    query_keys: jnp.ndarray,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """slots: (cap + budget,) int32; table_keys: (N, K) int32 (N >= 1);
    query_keys: (Q, K) int32, Q % QBLK == 0. Returns (Q,) int32 row index
    or -1."""
    cap = slots.shape[0] - PROBE_BUDGET
    q = query_keys.shape[0]
    grid = (q // QBLK,)
    kernel = functools.partial(_probe_kernel, cap=cap, budget=PROBE_BUDGET)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(slots.shape, lambda i: (0,)),  # table resident
            pl.BlockSpec(table_keys.shape, lambda i: (0, 0)),
            pl.BlockSpec((QBLK, query_keys.shape[1]), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((QBLK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((q,), jnp.int32),
        interpret=interpret,
    )(slots, table_keys, query_keys)
