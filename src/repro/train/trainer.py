"""Train step: xent loss, microbatch gradient accumulation (lax.scan with
donated carry), mixed precision, AdamW — the function launch/dryrun.py
lowers on the production mesh and examples/train_lm.py runs on CPU.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.transformer import ModelConfig, apply_model
from repro.train import optimizer as opt


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    adamw: opt.AdamWConfig = dataclasses.field(default_factory=opt.AdamWConfig)
    microbatches: int = 1  # split the global batch, accumulate grads


def xent_loss(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean token cross-entropy in fp32. labels -100 are masked."""
    mask = labels >= 0
    labels_safe = jnp.where(mask, labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels_safe[..., None], axis=-1)[..., 0]
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1)


def loss_fn(params, cfg: ModelConfig, inputs, labels):
    logits = apply_model(params, cfg, inputs)
    return xent_loss(logits, labels)


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). batch = {"inputs": (B, S[, D]), "labels": (B, S)}."""

    def train_step(params, opt_state, batch):
        mb = tcfg.microbatches
        if mb == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, cfg, batch["inputs"], batch["labels"])
        else:
            b = batch["inputs"].shape[0]
            assert b % mb == 0
            resh = lambda x: x.reshape(mb, b // mb, *x.shape[1:])  # noqa: E731
            micro = jax.tree.map(resh, batch)

            def acc_step(carry, mb_batch):
                loss_acc, grad_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(
                    params, cfg, mb_batch["inputs"], mb_batch["labels"]
                )
                grad_acc = jax.tree.map(jnp.add, grad_acc, grads)
                return (loss_acc + loss, grad_acc), None

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(acc_step, (jnp.float32(0), zero), micro)
            loss = loss / mb
            grads = jax.tree.map(lambda g: g / mb, grads)
        params, opt_state, metrics = opt.apply_updates(tcfg.adamw, params, grads, opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def init_train_state(key, cfg: ModelConfig, tcfg: TrainConfig):
    from repro.models.transformer import init_params

    params = init_params(key, cfg)
    opt_state = opt.init_state(tcfg.adamw, params)
    return params, opt_state
