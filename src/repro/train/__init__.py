from repro.train.optimizer import AdamWConfig, init_state, apply_updates, schedule
from repro.train.trainer import TrainConfig, make_train_step, init_train_state, xent_loss
from repro.train import checkpoint, compression, data, straggler

__all__ = [
    "AdamWConfig", "init_state", "apply_updates", "schedule",
    "TrainConfig", "make_train_step", "init_train_state", "xent_loss",
    "checkpoint", "compression", "data", "straggler",
]
