"""Straggler & failure monitoring (host-side control plane).

On a 1000+ node fleet the SPMD program itself cannot skip a slow host —
every collective is a barrier. What the control plane *can* do:
  1. detect stragglers from per-host step-time telemetry (robust z-score
     vs. the fleet median),
  2. decide to evict/replace hosts and trigger an elastic rescale
     (checkpoint -> new mesh -> restore; see checkpoint.py), and
  3. keep goodput accounting so the decision threshold is principled
     (evict when projected restart cost < projected straggler drag).

This module is that decision logic, kept pure/deterministic so it is
unit-testable without a fleet.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class StragglerPolicy:
    window: int = 20  # steps of telemetry per decision
    slow_factor: float = 1.5  # flag hosts slower than 1.5x fleet median
    min_flags: int = 3  # consecutive windows before eviction
    restart_cost_steps: float = 50.0  # checkpoint+rescale+restore, in steps


@dataclasses.dataclass
class HostState:
    flags: int = 0


class StragglerMonitor:
    def __init__(self, num_hosts: int, policy: StragglerPolicy | None = None):
        self.policy = policy or StragglerPolicy()
        self.hosts = [HostState() for _ in range(num_hosts)]
        self.history: list[np.ndarray] = []

    def observe(self, step_times: np.ndarray) -> dict:
        """step_times: (num_hosts,) seconds for the last window of steps.
        Returns {"slow": [host ids], "evict": [host ids]}."""
        med = float(np.median(step_times))
        slow = [
            i for i, t in enumerate(step_times) if t > self.policy.slow_factor * med
        ]
        evict = []
        for i, h in enumerate(self.hosts):
            if i in slow:
                h.flags += 1
            else:
                h.flags = 0
            if h.flags >= self.policy.min_flags and self._worth_evicting(step_times, i, med):
                evict.append(i)
                h.flags = 0
        self.history.append(step_times)
        return {"slow": slow, "evict": evict}

    def _worth_evicting(self, t: np.ndarray, host: int, med: float) -> bool:
        # drag per step if we keep the straggler (collectives run at its pace)
        drag = float(t[host]) - med
        if drag <= 0:
            return False
        # steps until restart pays for itself
        payback = self.policy.restart_cost_steps * med / drag
        horizon = 10 * self.policy.restart_cost_steps  # assume long jobs
        return payback < horizon


def reshard_plan(old_hosts: int, new_hosts: int, global_batch: int) -> dict:
    """Elastic rescale bookkeeping: new per-host batch and whether the
    global batch is preserved (it must be, for reproducibility)."""
    if global_batch % new_hosts:
        raise ValueError(f"global batch {global_batch} not divisible by {new_hosts} hosts")
    return {
        "per_host_batch": global_batch // new_hosts,
        "data_restart": "pure-function stream: continue at next step (data.py)",
        "checkpoint": "mesh-independent: restore with new shardings (checkpoint.py)",
    }
