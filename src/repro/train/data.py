"""Data pipeline: deterministic, resumable token streams + relational
sample selection through the Free Join engine (DESIGN.md Sec 5.1 — the
paper's technique applied at the framework layer).

Determinism & fault tolerance: batch(step, host) is a pure function of
(seed, step, host), so resume-after-failure = restore checkpoint + continue
at step+1 — no stream state to persist, no data replay drift. Elastic
rescale changes `num_hosts` and the per-host slice, not the global stream.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import free_join
from repro.core.engine import materialize
from repro.relational.relation import Relation
from repro.relational.schema import Atom, Query


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


def synthetic_batch(cfg: DataConfig, step: int, host: int = 0, num_hosts: int = 1):
    """Per-host slice of the global batch for `step` (pure function)."""
    assert cfg.global_batch % num_hosts == 0
    per_host = cfg.global_batch // num_hosts
    rng = np.random.default_rng((cfg.seed, step, host))
    tokens = rng.integers(0, cfg.vocab, (per_host, cfg.seq_len + 1), dtype=np.int32)
    return {"inputs": tokens[:, :-1], "labels": tokens[:, 1:]}


def _bigram_table(vocab: int, seed: int) -> np.ndarray:
    """A fixed sparse-ish bigram distribution: each token has 4 likely
    successors. Gives the LM a learnable signal (used by examples/tests)."""
    rng = np.random.default_rng(seed + 12345)
    return rng.integers(0, vocab, (vocab, 4))


def markov_batch(cfg: DataConfig, step: int, host: int = 0, num_hosts: int = 1):
    """Learnable synthetic stream: tokens follow a fixed bigram chain with
    90% probability (10% noise). Same determinism contract as
    synthetic_batch."""
    assert cfg.global_batch % num_hosts == 0
    per_host = cfg.global_batch // num_hosts
    succ = _bigram_table(cfg.vocab, cfg.seed)
    rng = np.random.default_rng((cfg.seed, step, host))
    toks = np.empty((per_host, cfg.seq_len + 1), dtype=np.int32)
    toks[:, 0] = rng.integers(0, cfg.vocab, per_host)
    choice = rng.integers(0, 4, (per_host, cfg.seq_len))
    noise = rng.random((per_host, cfg.seq_len)) < 0.1
    noise_tok = rng.integers(0, cfg.vocab, (per_host, cfg.seq_len), dtype=np.int32)
    for t in range(cfg.seq_len):
        nxt = succ[toks[:, t], choice[:, t]]
        toks[:, t + 1] = np.where(noise[:, t], noise_tok[:, t], nxt)
    return {"inputs": toks[:, :-1], "labels": toks[:, 1:]}


def select_corpus_samples(
    docs: Relation,
    quality: Relation,
    dedup: Relation,
    min_quality: int,
) -> np.ndarray:
    """Relational sample selection: which documents enter training?

        Keep(doc, shard) :- Docs(doc, shard, lang),
                            Quality(doc, score >= min_quality),
                            Dedup(doc, canonical == doc)

    Runs as a Free Join (plan converted+factored from the cost-based binary
    plan). Returns selected doc ids. On a fleet this runs on the host data
    workers; it is the paper's engine doing framework work.
    """
    q = Query(
        [
            Atom("Docs", ("doc", "shard", "lang")),
            Atom("Quality", ("doc", "score")),
            Atom("Dedup", ("doc", "canonical")),
        ]
    )
    qual = quality.select(np.asarray(quality.columns["score"]) >= min_quality)
    ded = dedup.select(np.asarray(dedup.columns["canonical"]) == np.asarray(dedup.columns["doc"]))
    bound, mult = free_join(q, {"Docs": docs, "Quality": qual, "Dedup": ded})
    out = materialize(bound, mult, ("doc",))
    return np.unique(out["doc"])
