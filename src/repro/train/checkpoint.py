"""Fault-tolerant checkpointing with mesh-independent layout.

Design for 1000+ nodes (DESIGN.md Sec 3):
  * Leaves are saved as full (unsharded) arrays keyed by pytree path in one
    .npz per checkpoint, plus a JSON manifest {step, leaf paths, dtypes}.
    Because the on-disk layout carries no mesh information, a restore may
    target *any* mesh: `restore(..., shardings=...)` device_puts each leaf
    with the new sharding — this is the elastic-rescale path (checkpoint at
    N pods, resume at M pods).
  * Writes are atomic (tmp dir + rename) so a node failure mid-write never
    corrupts the latest checkpoint; `latest_step` scans completed manifests
    only.
  * On a real fleet each host would write its owned shards
    (process-local slices) — the manifest/atomic-rename protocol is the
    same; here a single host owns everything, which keeps the semantics
    testable on CPU.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keyed = {jax.tree_util.keystr(path): leaf for path, leaf in flat}
    return keyed, treedef


def save(ckpt_dir: str, step: int, tree) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp-{step}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    keyed, _ = _flatten(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in keyed.items()}
    # numpy can't serialize bf16/fp8 (ml_dtypes): store them as raw views
    packed = {}
    for k, a in arrays.items():
        raw = a.dtype.kind not in "fiub?" or a.dtype.name.startswith("bfloat")
        packed[k] = a.view(np.uint16 if a.dtype.itemsize == 2 else np.uint8) if raw else a
    np.savez(os.path.join(tmp, "leaves.npz"), **packed)
    manifest = {
        "step": step,
        "leaves": {k: {"shape": list(a.shape), "dtype": str(a.dtype)} for k, a in arrays.items()},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like, shardings=None):
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs). With `shardings` (a matching pytree of Sharding or a
    single Sharding), each leaf is device_put with the *new* placement —
    restoring a checkpoint from a different mesh shape reshards here."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(path, "leaves.npz"))
    keyed_like, treedef = _flatten(like)
    leaves = []
    for k, proto in keyed_like.items():
        arr = data[k]
        want = np.dtype(proto.dtype)
        if arr.dtype != want and arr.dtype.kind == "u" and arr.dtype.itemsize == want.itemsize:
            arr = arr.view(want)  # raw-packed custom dtype (bf16/fp8)
        if tuple(arr.shape) != tuple(proto.shape):
            raise ValueError(f"leaf {k}: ckpt shape {arr.shape} != expected {proto.shape}")
        arr = arr.astype(proto.dtype)
        leaves.append(arr)
    tree = jax.tree.unflatten(treedef, leaves)
    if shardings is not None:
        tree = (
            jax.tree.map(jax.device_put, tree, shardings)
            if isinstance(shardings, (dict, list, tuple))
            else jax.tree.map(lambda a: jax.device_put(a, shardings), tree)
        )
    return tree
