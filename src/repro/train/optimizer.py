"""AdamW with warmup+cosine schedule, global-norm clipping, and a
configurable moment dtype (bf16 moments for the >=398B archs so optimizer
state fits HBM — see DESIGN.md Sec 6). Pure pytree-in/pytree-out; no optax
dependency.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    moment_dtype: str = "float32"


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    prog = (step - cfg.warmup_steps) / jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(cfg: AdamWConfig, params: Any):
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)  # noqa: E731
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


def apply_updates(cfg: AdamWConfig, params, grads, state):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    dt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = mf / bc1
        vhat = vf / bc2
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * step_).astype(p.dtype)
        return newp, mf.astype(dt), vf.astype(dt)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"step": step, "m": new_m, "v": new_v}, {"lr": lr, "grad_norm": gnorm}
