"""Int8 error-feedback gradient compression for data-parallel all-reduce.

The algebra is the standard EF-SGD scheme: each step quantizes (grad +
error) to int8 with a shared power-of-two-free scale, all-reduces the int8
payload, dequantizes, and carries the quantization residual into the next
step. On TPU the wire format is int8 (4x reduction of DP all-reduce bytes);
on this CPU container XLA widens the psum to int32 — the *algebra* and the
error-feedback state are what the tests pin down (see DESIGN.md Sec 3).

Usage inside a shard_map'd train step:
    g_global, err = compressed_psum(g_local, err, axis="data")
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _quantize(x: jnp.ndarray, scale: jnp.ndarray):
    return jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)


def compressed_psum(grad, err, axis: str):
    """Per-leaf int8 error-feedback psum over `axis`.

    grad/err: pytrees of fp arrays (err same shapes, fp32). Returns
    (mean-reduced fp32 grads, new error state)."""
    n = jax.lax.psum(1, axis)

    def one(g, e):
        x = g.astype(jnp.float32) + e
        amax = jnp.max(jnp.abs(x))
        scale = jax.lax.pmax(amax, axis) / 127.0 + 1e-12
        q = _quantize(x, scale)
        deq_local = q.astype(jnp.float32) * scale
        new_err = x - deq_local
        total = jax.lax.psum(q.astype(jnp.int32), axis)
        return total.astype(jnp.float32) * scale / n, new_err

    flat_g, tdef = jax.tree.flatten(grad)
    flat_e = jax.tree.leaves(err)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        jax.tree.unflatten(tdef, [o[0] for o in outs]),
        jax.tree.unflatten(tdef, [o[1] for o in outs]),
    )


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
