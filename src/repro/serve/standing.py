"""Standing queries over streaming ingest: incremental view maintenance.

JoinServeEngine answers a query once; this engine keeps queries ANSWERED —
each registered query's result is maintained as the base relations mutate
through the relcache delta API (append/delete). The refresh loop is the
continuous-workload architecture the ROADMAP's streaming item calls for
(one engine serving both plan shapes, Kaboli et al., arXiv 2505.19918),
built from three pieces the repo already has:

* The versioned TRIE CACHE (compiled.TrieCache): a refresh over a mutated
  base relation pays one delta merge (sort the delta, splice the sorted
  run) or tombstone weight refresh — never a full rebuild.
* STAGE-BUFFER FINGERPRINTS: a bushy plan's stages are driven here by one
  AdaptiveExecutor each, instead of one fused chain program, exactly so a
  stage's inputs can be fingerprinted between runs. A stage's fingerprint
  covers every input: base relations by mutation version (or column object
  identity for never-mutated ones) and upstream stages by their run
  counter. Unchanged fingerprint -> the stage is SKIPPED and its cached
  device output buffers (and the weighted tries consumers built from them)
  are replayed verbatim; only the stages downstream of an actually-changed
  input recompute.
* PLAN TEMPLATES (serve.templates.canonicalize): standing queries are
  registered through the same canonicalization as JoinServeEngine
  requests, so two tenants' spellings of one query share a single set of
  per-stage runners, with the lifted constants as the only per-query
  state.

The observable contract (tests lock the counters): ingest into a relation
only the root stage reads recomputes exactly that stage; a refresh with no
mutations at all recomputes nothing.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults, relcache
from repro.core.api import ExecOptions, _stage_plans, free_join
from repro.core.capacity import CapacityQuotaError, plan_chain_capacities
from repro.core.compiled import (
    PAD_KEY,
    TRIE_CACHE,
    AdaptiveExecutor,
    _build_weighted_jit,
    device_columns,
    materialize_compiled,
)
from repro.core.optimizer import JoinOrderOptimizer, Stats
from repro.core.plan import BinaryPlan
from repro.relational.relation import Relation
from repro.relational.schema import Query
from repro.serve.templates import PlanTemplate, canonicalize


class _StageState:
    """Per-query, per-stage maintenance state: the last run's fingerprint,
    the cached device output buffers (non-root stages), the weighted tries
    consumers built from them (keyed by consumer level layout), and the run
    counter downstream fingerprints embed."""

    __slots__ = ("fingerprint", "out", "tries", "runs")

    def __init__(self):
        self.fingerprint = None
        self.out = None  # (bound, valid, mult) device buffers
        self.tries: dict = {}  # (levels, probed) -> weighted StaticTrie
        self.runs = 0


def _fp_equal(a, b) -> bool:
    """Fingerprint comparison. Column entries are numpy arrays compared by
    IDENTITY — `==` would be elementwise, and the fingerprint holding the
    strong reference is what makes identity sound (no id() reuse while the
    old fingerprint is alive)."""
    if a is None or b is None or len(a) != len(b):
        return False
    for pa, pb in zip(a, b):
        if len(pa) != len(pb):
            return False
        for xa, xb in zip(pa, pb):
            if isinstance(xa, np.ndarray) or isinstance(xb, np.ndarray):
                if xa is not xb:
                    return False
            elif xa != xb:
                return False
    return True


@dataclasses.dataclass
class StandingQuery:
    """Handle for one registered query: `result` always holds the answer as
    of the last refresh; `result_version` bumps each time a refresh actually
    recomputed the root stage."""

    qid: int
    template: PlanTemplate
    consts: np.ndarray
    states: list[_StageState]
    stage_consts: list[np.ndarray | None]
    result: object = None
    result_version: int = 0
    # "eager" while the last refresh fell back to the host engine after a
    # recoverable device fault; cleared by the next successful compiled
    # root recompute
    degraded_to: str | None = None

    @property
    def states_by_name(self) -> dict:
        return dict(zip(self._stage_names, self.states))

    _stage_names: tuple = ()


class StandingQueryEngine:
    """register() standing queries, refresh() their results incrementally.

    Pass `engine=` a JoinServeEngine to share its ExecOptions (so templates
    canonicalized here carry the same key a submit() of the same query
    would); otherwise supply `options` directly. Per-stage runners are
    cached per template key: every standing query of one template shares
    them, constants being the only per-query input.

    `ingest(rel, delta_cols)` is the streaming front door: one
    relcache.append (delta trie merge downstream) followed by a refresh of
    every registered query. Counters: `stage_runs` (stage executions),
    `stages_skipped` (fingerprint hits that replayed cached buffers),
    `stages_recomputed` (fingerprint misses)."""

    def __init__(
        self,
        *,
        engine=None,
        options: ExecOptions | None = None,
    ):
        self.options = engine.options if engine is not None else (options or ExecOptions())
        self.queries: list[StandingQuery] = []
        self._next_qid = 0
        # template key -> tuple of (name, plan, AdaptiveExecutor, stage filter
        # vars with their index into the template's consts vector)
        self._runners: dict = {}
        self.stage_runs = 0
        self.stages_skipped = 0
        self.stages_recomputed = 0
        # refreshes that fell back to the eager host engine after a
        # recoverable fault — the result stays correct, the counter says
        # the compiled path needs attention
        self.degraded_refreshes = 0

    # ---- intake -------------------------------------------------------
    def register(
        self,
        query: Query,
        relations: dict[str, Relation],
        filters: dict[str, int] | None = None,
        *,
        agg: str | None = "count",
        plan_tree=None,
    ) -> StandingQuery:
        """Canonicalize, plan, and compute the initial result. The returned
        handle's `result` is live: each refresh() updates it in place."""
        template, consts = canonicalize(
            query, relations, filters, plan_tree=plan_tree, agg=agg, options=self.options
        )
        runners = self._acquire_stage_runners(template)
        sq = StandingQuery(
            qid=self._next_qid,
            template=template,
            consts=consts,
            states=[_StageState() for _ in runners],
            stage_consts=[
                np.asarray([consts[idx] for _v, idx in fv], np.int32) if fv else None
                for _n, _p, _r, fv in runners
            ],
        )
        sq._stage_names = tuple(n for n, _p, _r, _fv in runners)
        self._next_qid += 1
        self.queries.append(sq)
        self._refresh_query(sq, runners)
        return sq

    def _acquire_stage_runners(self, template: PlanTemplate):
        runners = self._runners.get(template.key)
        if runners is not None:
            return runners
        o = template.options
        rels = dict(template.relations)
        stats = Stats(rels, cached=True)
        tree = template.plan_tree
        if tree is None:
            tree = JoinOrderOptimizer(
                level=o.optimize_level,
                safety=o.safety,
                compact_threshold=o.compact_threshold,
                feedback=relcache.FEEDBACK,
            ).choose(template.query, rels, stats=stats)
        stages = _stage_plans(template.query, tree)
        chain = plan_chain_capacities(
            stages,
            stats=stats,
            safety=o.safety,
            compact_threshold=o.compact_threshold,
            feedback=relcache.FEEDBACK,
        )
        # first-binder filter assignment, mirroring make_chain_executor: a
        # var's selection runs in the first stage that binds it, and dead
        # rows carry mult 0 into every downstream weighted trie
        unassigned = {v: i for i, v in enumerate(template.filter_vars)}
        built = []
        for i, ((name, plan), cp) in enumerate(zip(stages, chain.stages)):
            fv = tuple(
                (v, unassigned.pop(v))
                for v in tuple(plan.query.variables)
                if v in unassigned
            )
            runner = AdaptiveExecutor(
                plan,
                cp,
                impl=o.impl,
                budget=o.budget,
                agg=template.agg if i == len(stages) - 1 else None,
                jit=o.jit,
                tighten=True,
                filter_vars=tuple(v for v, _ in fv),
            )
            built.append((name, plan, runner, fv))
        assert not unassigned, f"filter vars bound by no stage: {sorted(unassigned)}"
        runners = tuple(built)
        self._runners[template.key] = runners
        return runners

    # ---- maintenance --------------------------------------------------
    def ingest(self, rel: Relation, delta_cols: dict) -> list[StandingQuery]:
        """Append a delta through the relcache mutation API, then refresh
        every standing query. Returns the queries whose result changed."""
        relcache.append(rel, delta_cols)
        return self.refresh()

    def refresh(self) -> list[StandingQuery]:
        """Re-maintain every registered query: stages whose fingerprints
        moved recompute (delta-merged tries flowing in from the trie
        cache), the rest replay cached buffers. Returns the queries whose
        root stage actually re-ran."""
        changed = []
        for sq in self.queries:
            if self._refresh_query(sq, self._runners[sq.template.key]):
                changed.append(sq)
        return changed

    def _refresh_query(self, sq: StandingQuery, runners) -> bool:
        rels = sq.template.relations
        states_by_name = sq.states_by_name
        root_changed = False
        for i, (_name, plan, runner, _fv) in enumerate(runners):
            state = sq.states[i]
            stage_names = set(sq._stage_names[:i])
            fp = self._stage_fp(plan, stage_names, rels, states_by_name)
            is_root = i == len(runners) - 1
            self.stage_runs += 1
            if _fp_equal(fp, state.fingerprint) and (is_root or state.out is not None):
                self.stages_skipped += 1
                continue
            self.stages_recomputed += 1
            try:
                data = self._stage_data(plan, stage_names, rels, runner, states_by_name)
                out = runner(data, sq.stage_consts[i])
            except Exception as e:
                # a standing query has no co-batched tenants to protect, so
                # a runtime capacity quota degrades like any device fault:
                # answer from the eager host engine, keep the result live
                if not (faults.recoverable(e) or isinstance(e, CapacityQuotaError)):
                    raise
                self._recover_eager(sq)
                return True
            if is_root:
                if sq.template.agg == "count":
                    sq.result = int(jax.device_get(out))
                else:
                    sq.result = materialize_compiled(*out)
                sq.result_version += 1
                sq.degraded_to = None
                root_changed = True
            else:
                state.out = out
                state.tries = {}  # consumers rebuild from the fresh buffers
            state.fingerprint = fp
            state.runs += 1
        return root_changed

    def _recover_eager(self, sq: StandingQuery) -> None:
        """Fault recovery: answer the query on the eager host engine over
        live-row snapshots and invalidate every cached stage state, so the
        next refresh rebuilds the compiled pipeline from scratch (clearing
        `degraded_to` if it succeeds)."""
        t = sq.template
        filters = {v: int(c) for v, c in zip(t.filter_vars, sq.consts)}
        tree = t.plan_tree if isinstance(t.plan_tree, BinaryPlan) else None
        rels = {a: relcache.live_relation(r) for a, r in t.relations.items()}
        out = free_join(t.query, rels, tree, agg=t.agg, filters=filters or None)
        sq.result = int(out) if t.agg == "count" else out
        sq.result_version += 1
        sq.degraded_to = "eager"
        self.degraded_refreshes += 1
        for state in sq.states:
            state.fingerprint = None
            state.out = None
            state.tries = {}

    def _stage_fp(self, plan, stage_names, rels, states_by_name):
        """One stage's input fingerprint: upstream stages by run counter,
        base relations by mutation version (strong column refs make the
        identity comparison in _fp_equal sound for never-mutated ones)."""
        parts = []
        for a in sorted({sa.alias for node in plan.nodes for sa in node}):
            if a in stage_names:
                parts.append((a, "stage", states_by_name[a].runs))
                continue
            rel = rels[a]
            st = relcache.mutation_state(rel)
            if st is not None:
                parts.append((a, "mut", id(rel), st.version))
            else:
                parts.append((a, "cols", *(rel.columns[v] for v in rel.schema)))
        return tuple(parts)

    def _stage_data(self, plan, stage_names, rels, runner, states_by_name):
        """Assemble the stage's rel_data dict: base aliases from the
        delta-aware trie cache (or live-row columns when the schedule reads
        raw), upstream stage aliases as weighted tries built once per
        upstream run from the cached output buffers."""
        data = {}
        for a in {sa.alias for node in plan.nodes for sa in node}:
            if a in stage_names:
                up = states_by_name[a]
                lo = runner.schedule.level_ops[a]
                key = (lo.levels, lo.probed)
                trie = up.tries.get(key)
                if trie is None:
                    bound, valid, mult = up.out
                    flat = [v for lv in lo.levels for v in lv]
                    cols = {v: jnp.where(valid, bound[v], PAD_KEY) for v in flat}
                    w = jnp.where(valid, mult, 0).astype(jnp.int32)
                    trie = _build_weighted_jit(cols, w, lo, runner.impl, runner.budget)
                    up.tries[key] = trie
                data[a] = trie
                continue
            rel = rels[a]
            lo = runner._alias_lops.get(a)
            if lo is not None:
                data[a] = TRIE_CACHE.get(
                    rel, device_columns(rel), lo, impl=runner.impl, budget=runner.budget
                )
            else:
                data[a] = device_columns(relcache.live_relation(rel))
        return data
