"""Plan templates: the canonical form a serving engine caches plans under.

Two tenants rarely send byte-identical queries — one writes
``Q() :- Edges1(x,y), Edges2(y,z)`` where another writes
``Q() :- E(x,y), F(y,z)`` over the same base relations, and both carry
their own selection constants (``x = 7`` vs ``x = 42``). Structurally
these are ONE query: same relations, same join shape, same head, same
*set* of filtered variables. `canonicalize` maps every member of that
equivalence class to a single `PlanTemplate`, so they share one binary
plan, one capacity plan, and one compiled executor:

* **alias alpha-renaming** — atoms are sorted by (relation name, vars)
  and re-aliased ``t0..tn`` in that order, erasing whatever names the
  tenant chose. Variables are NOT renamed: they are the relations'
  column names (``rel.columns[v]``), so they are already canonical —
  two queries over the same relations that disagree on variable names
  disagree on real schema, not on spelling.
* **constant lifting** — filters ``{var: const}`` contribute only their
  sorted var tuple to the template; the constants become a runtime
  int32 vector (`consts`) fed to the constant-parameterized executor.
  N queries differing only in constants hit one cache entry.

What does NOT collapse (by construction of `key`): different head
projections, different aggregates, different ExecOptions, a different
explicit plan tree, different filtered-var sets, and different base
relation objects (identity via id(), made safe by the runner cache's
weakref finalizers) all produce distinct templates.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.api import ExecOptions
from repro.core.plan import BinaryPlan
from repro.relational.relation import Relation
from repro.relational.schema import Atom, Query


@dataclass(frozen=True, eq=False)
class PlanTemplate:
    """A canonicalized query ready for template-keyed serving: the
    alpha-renamed query/relations/plan plus the hashable `key` the engine
    groups and caches by. `filter_vars` is the sorted tuple of filtered
    variables; per-request constants live OUTSIDE the template (see
    `canonicalize`'s second return value)."""

    key: tuple
    query: Query = field(hash=False)
    relations: dict[str, Relation] = field(hash=False)
    plan_tree: BinaryPlan | Atom | None = field(hash=False)
    filter_vars: tuple[str, ...]
    agg: str | None
    options: ExecOptions

    def __eq__(self, other):
        return isinstance(other, PlanTemplate) and self.key == other.key

    def __hash__(self):
        return hash(self.key)


def _plan_sig(tree, alias_map: dict[str, str]):
    """Deterministic render of a binary plan tree under canonical aliases
    (None stays None: both sides will let the optimizer pick, and the
    optimizer is deterministic given the canonical query + stats)."""
    if tree is None:
        return None

    def go(node):
        if isinstance(node, Atom):
            return f"{node.name}:{alias_map[node.alias]}({','.join(node.vars)})"
        return f"({go(node.left)} {go(node.right)})"

    return go(tree)


def _rebuild_plan(tree, canon: dict[str, Atom]):
    if tree is None or isinstance(tree, Atom):
        return canon[tree.alias] if isinstance(tree, Atom) else None
    return BinaryPlan(_rebuild_plan(tree.left, canon), _rebuild_plan(tree.right, canon))


def recanonicalize(template: PlanTemplate) -> tuple[PlanTemplate, np.ndarray]:
    """Run `canonicalize` over a template's own canonical query (with
    placeholder constants). Canonicalization must be a fixed point —
    ``recanonicalize(t).key == t.key`` — or two spellings of one query can
    land on distinct template keys and each compile their own executor.
    The static verifier (repro.analysis) checks this per template; keeping
    the probe here keeps it honest against the real `canonicalize`."""
    return canonicalize(
        template.query,
        template.relations,
        dict.fromkeys(template.filter_vars, 0),
        plan_tree=template.plan_tree,
        agg=template.agg,
        options=template.options,
    )


def canonicalize(
    query: Query,
    relations: dict[str, Relation],
    filters: dict[str, int] | None = None,
    *,
    plan_tree: BinaryPlan | Atom | None = None,
    agg: str | None = "count",
    options: ExecOptions | None = None,
) -> tuple[PlanTemplate, np.ndarray]:
    """Canonicalize one request into (template, consts).

    `consts` is the request's int32 constant vector in `filter_vars`
    (sorted) order — the only per-request payload left after
    canonicalization, and exactly the `filter_consts` argument of the
    template's compiled runner."""
    options = options or ExecOptions()
    filters = dict(filters or {})
    unknown = set(filters) - set(query.variables)
    if unknown:
        raise ValueError(f"filter vars not in the query: {sorted(unknown)}")
    # alias alpha-renaming: sort atoms structurally, re-alias t0..tn.
    # Ties (true self-joins: same relation name AND same vars) keep input
    # order — the tied atoms are interchangeable precisely when their
    # backing relations match, which the key's id() component checks.
    order = sorted(range(len(query.atoms)), key=lambda i: (query.atoms[i].name, query.atoms[i].vars))
    canon: dict[str, Atom] = {}
    atoms: list[Atom] = []
    for rank, i in enumerate(order):
        a = query.atoms[i]
        ca = Atom(a.name, a.vars, f"t{rank}")
        canon[a.alias] = ca
        atoms.append(ca)
    # head ORDER is an artifact of atom order (the default head lists vars
    # by first appearance), and execution depends only on the head SET —
    # agg=None results are var-keyed dicts, project in any order you like.
    # Re-ordering it into canonical variable order makes two spellings of
    # the same projection one template; a different head *set* still splits.
    hset = set(query.head)
    chead = tuple(v for v in Query(atoms).variables if v in hset)
    cquery = Query(atoms, head=chead)
    crels = {canon[a.alias].alias: relations[a.alias] for a in query.atoms}
    alias_map = {old: ca.alias for old, ca in canon.items()}
    cplan = _rebuild_plan(plan_tree, canon)
    filter_vars = tuple(sorted(filters))
    key = (
        tuple((a.name, a.vars, a.alias) for a in atoms),
        cquery.head,
        agg,
        options,
        filter_vars,
        _plan_sig(plan_tree, alias_map),
        tuple(sorted((al, id(r)) for al, r in crels.items())),
    )
    consts = np.asarray([filters[v] for v in filter_vars], np.int32)
    template = PlanTemplate(
        key=key,
        query=cquery,
        relations=crels,
        plan_tree=cplan,
        filter_vars=filter_vars,
        agg=agg,
        options=options,
    )
    return template, consts
