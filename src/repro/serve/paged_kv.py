"""Paged KV-cache allocator.

The page table is a relation (seq_id, page_no) -> physical slot, and the
lookup is exactly the join engine's batched hash probe (DESIGN.md Sec 5.3):
we reuse the vectorized open-addressing table from relational/npkit (the
host twin of the Pallas hash_probe kernel). Allocation/free happens on the
host control plane; the device side sees only dense page-index arrays.
"""
from __future__ import annotations

import numpy as np

from repro.relational.npkit import HashTable


class PagedAllocator:
    def __init__(self, num_pages: int, page_size: int):
        self.num_pages = num_pages
        self.page_size = page_size
        self.free = list(range(num_pages - 1, -1, -1))
        self.owner: dict[int, list[int]] = {}  # seq_id -> [slots in page order]
        self._table: HashTable | None = None
        self._dirty = True

    def alloc(self, seq_id: int, num_tokens: int) -> list[int]:
        """Ensure seq has pages for `num_tokens`; returns new slots."""
        pages = self.owner.setdefault(seq_id, [])
        need = -(-num_tokens // self.page_size) - len(pages)
        if need > len(self.free):
            raise MemoryError(f"paged KV pool exhausted ({need} > {len(self.free)})")
        new = [self.free.pop() for _ in range(max(0, need))]
        pages.extend(new)
        self._dirty = bool(new)
        return new

    def release(self, seq_id: int) -> None:
        self.free.extend(self.owner.pop(seq_id, []))
        self._dirty = True

    def _rebuild(self) -> None:
        seqs, pnos, slots = [], [], []
        for sid, pages in self.owner.items():
            for i, slot in enumerate(pages):
                seqs.append(sid)
                pnos.append(i)
                slots.append(slot)
        self._keys = [np.asarray(seqs, np.int64), np.asarray(pnos, np.int64)]
        self._vals = np.asarray(slots, np.int64)
        self._table = HashTable(self._keys)
        self._dirty = False

    def lookup(self, seq_ids: np.ndarray, page_nos: np.ndarray) -> np.ndarray:
        """Batched page-table probe: physical slot per (seq, page), -1 miss."""
        if self._dirty or self._table is None:
            self._rebuild()
        idx = self._table.probe([np.asarray(seq_ids, np.int64), np.asarray(page_nos, np.int64)])
        return np.where(idx >= 0, self._vals[np.clip(idx, 0, None)], -1)

    def page_index(self, seq_ids: list[int], max_pages: int) -> np.ndarray:
        """Dense (B, max_pages) slot matrix for the device (-1 = unused)."""
        out = np.full((len(seq_ids), max_pages), -1, dtype=np.int32)
        for i, sid in enumerate(seq_ids):
            pages = self.owner.get(sid, [])[:max_pages]
            out[i, : len(pages)] = pages
        return out
