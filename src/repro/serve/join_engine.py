"""Multi-tenant join-query serving over the compiled Free Join path.

The decode engine next door (engine.py) serves token streams; this engine
serves *queries*. Same TPU discipline, different payload: fixed-width
request slots so the compiled executor never changes shape, an occupancy
mask instead of a varying batch, and a host control plane that admits,
groups, dispatches, and retires.

The pipeline per `step()`:

1. **Pick a template, round-robin.** Every submitted request was
   canonicalized on arrival (templates.canonicalize): alpha-renamed
   aliases, constants lifted out. Requests sharing a template key —
   however differently their tenants spelled the query — are batchable
   against ONE compiled runner. Each step serves the *next* queued
   template in rotation (not the head-of-line one): a tenant streaming
   requests on one template can fill the queue front forever, and
   first-template-wins would starve every other template behind it.
2. **Admit.** The runner's capacity plan is known before any compile;
   each request is checked against its tenant's measured-cost quota
   (`max_dispatch_us` vs the template's dispatch-time EMA — see below)
   and its `max_plan_cells` quota, and rejected with zero XLA work on
   violation.
3. **Dispatch one vmapped probe.** Up to `slots` co-template requests run
   as one batched executor call over the shared cached tries: the int32
   constants matrix (slots, F) is the only per-lane input. Dead slots
   are padded with a live lane's constants (they compute a duplicate
   answer that is simply not read back).
4. **Evict on quota.** If the adaptive runner raises CapacityQuotaError,
   the named lane's request is rejected, its slot re-padded, and the
   remaining requests re-dispatched against the same compiled executor —
   co-batched tenants never pay a recompile for a pathological neighbor.

Filterless templates (F=0) have nothing to vary per lane, so the whole
group is served by ONE unbatched call whose result every member shares —
degenerate batching, and the cheapest possible kind.

The engine also keeps a per-template exponential moving average of
measured dispatch wall time (`cost_ema_us`, updated on every dispatch —
cold compiles included, decayed by later warm dispatches). Admission
consults it alongside the planned cells: planning says what a template
*should* cost, the EMA says what it *did* cost last time(s).

**Resilience (the degradation ladder).** A fault the quota machinery has
no protocol for — a compile failure, device RESOURCE_EXHAUSTED, a
memory-governor shed (core.membudget) — never crashes step(). The group
descends a ladder instead, each rung recorded on the served handles as
`degraded_to`:

    full-width batch -> halved batch -> unbatched kill-mode -> eager host

The eager rung cannot fail for device reasons (it is the numpy engine),
so every admitted request completes — possibly degraded, never crashed.
Two more production guards ride along: per-request `deadline_ms`
(submit-relative; expired requests are rejected with reason "deadline"
rather than dispatched late) and jittered exponential backoff between
quota-eviction rounds, so an overflow storm cannot hot-loop the host
while co-batched tenants wait. Eviction retry budgets are charged to the
OFFENDER: a tenant whose lanes keep blowing the growth quota exhausts
its own max_retries and is rejected wholesale; compliant neighbors are
re-dispatched free of charge (the batch strictly shrinks, so the loop
terminates structurally).
"""
from __future__ import annotations

import dataclasses
import random
import time
from collections import deque

import numpy as np

from repro.core import api, faults, relcache
from repro.core.api import ExecOptions, _acquire_runner, free_join
from repro.core.capacity import CapacityQuotaError
from repro.core.plan import BinaryPlan
from repro.relational.relation import Relation
from repro.relational.schema import Query
from repro.serve.admission import AdmissionController, AdmissionError
from repro.serve.templates import PlanTemplate, canonicalize


@dataclasses.dataclass
class JoinRequest:
    rid: int
    tenant: str
    template: PlanTemplate | None  # None: rejected at submit-time verification
    consts: np.ndarray  # (F,) int32 — the lifted selection constants
    result: object = None
    error: Exception | None = None
    done: bool = False
    # which ladder rung served this request, if any ("halved" | "unbatched"
    # | "eager"); None means the full-width fast path answered it
    degraded_to: str | None = None
    # submit-relative deadline: past it the request is rejected (reason
    # "deadline") instead of dispatched late
    deadline_ms: float | None = None
    t_submit: float = 0.0


class JoinServeEngine:
    """Concurrent join serving: submit() canonicalizes, step() batches.

    slots: fixed dispatch width — every batched runner is compiled at this
    width once and reused for any group size up to it. options: compiled-
    path ExecOptions shared by all templates this engine builds (a request
    may still carry its own via canonicalize). admission: quota controller
    (default: no quotas). The engine keys its runners in a scoped
    namespace of the process runner cache, so template-canonicalized keys
    can never collide with compiled_free_join's verbatim keys."""

    def __init__(
        self,
        *,
        slots: int = 8,
        options: ExecOptions | None = None,
        admission: AdmissionController | None = None,
        cache=None,
    ):
        if slots < 1:
            raise ValueError("slots must be >= 1")
        self.slots = slots
        self.options = options or ExecOptions()
        self.admission = admission or AdmissionController()
        self._cache = (cache if cache is not None else api._runner_cache).scoped("join-templates")
        self.queue: deque[JoinRequest] = deque()
        self._next_rid = 0
        self._rr = 0  # round-robin cursor over queued templates
        self.dispatches = 0  # batched executor calls issued
        self.served = 0  # requests completed successfully
        # template key -> EMA of measured dispatch wall time (us). Bounded
        # in practice by the runner cache's LRU (dead templates stop being
        # re-submitted); alpha 0.3 forgets a cold compile in a few warm
        # dispatches.
        self.cost_ema_us: dict = {}
        self.ema_alpha = 0.3
        # resilience counters: requests served per ladder rung, faults the
        # ladder absorbed, deadline rejections — the chaos suite's contract
        self.degraded = {"halved": 0, "unbatched": 0, "eager": 0}
        self.faults_absorbed = 0
        self.deadline_rejected = 0
        # jittered exponential backoff between quota-eviction rounds: base
        # doubles per eviction up to the cap, jitter is deterministic
        # (seeded) so chaos runs reproduce
        self.backoff_base_ms = 1.0
        self.backoff_cap_ms = 50.0
        self.backoff_jitter = 0.25
        self._jitter_rng = random.Random(0xC0FFEE)

    # ---- intake -------------------------------------------------------
    def submit(
        self,
        query: Query,
        relations: dict[str, Relation],
        filters: dict[str, int] | None = None,
        *,
        tenant: str = "default",
        agg: str | None = "count",
        plan_tree=None,
        deadline_ms: float | None = None,
    ) -> JoinRequest:
        """Canonicalize, statically verify, and enqueue one query; returns
        its JoinRequest handle (result/error/done are filled by step()).

        Verification failures REJECT the request (error set, done=True,
        admission counter bumped) instead of raising or enqueuing: a raise
        would crash the submitting tenant's whole intake loop, and an
        enqueued invalid plan would detonate mid-dispatch inside a batch
        shared with innocent co-template tenants. A rejected handle comes
        back immediately and never touches the serving loop."""
        from repro.analysis.diagnostics import PlanVerificationError
        from repro.analysis.planlint import lint_query, lint_template, lint_tree

        # the ORIGINAL query, pre-canonicalization: canonicalize silently
        # drops head vars no atom binds, so the template would look clean
        rep = lint_query(query)
        rep.extend(lint_tree(query, plan_tree)[0])
        try:
            rep.raise_errors()
            template, consts = canonicalize(
                query, relations, filters, plan_tree=plan_tree, agg=agg,
                options=self.options,
            )
            lint_template(template).raise_errors()
        except (PlanVerificationError, ValueError) as e:
            req = JoinRequest(
                rid=self._next_rid, tenant=tenant,
                template=None, consts=np.zeros(0, np.int32),  # type: ignore[arg-type]
            )
            self._next_rid += 1
            self.admission.reject_runtime(tenant, reason="invalid")
            self._reject(req, e)
            return req
        req = JoinRequest(
            rid=self._next_rid, tenant=tenant, template=template, consts=consts,
            deadline_ms=deadline_ms, t_submit=time.perf_counter(),
        )
        self._next_rid += 1
        self.queue.append(req)
        return req

    # ---- serving loop -------------------------------------------------
    def step(self) -> list[JoinRequest]:
        """One engine iteration: pick the next queued template in round-robin
        rotation, pull every queued co-template request into up to `slots`
        lanes, and serve them with one dispatch. Returns the requests retired
        this step (completed or rejected).

        Rotation, not head-of-line: with first-template-wins, a tenant
        streaming requests on one template keeps the queue front occupied
        and every other template waits forever. The rotation cursor walks
        the arrival-ordered list of *distinct* queued templates, so k live
        templates each get every k-th dispatch regardless of queue depth."""
        if not self.queue:
            return []
        templates: list[PlanTemplate] = []
        for r in self.queue:
            if r.template not in templates:
                templates.append(r.template)
        chosen = templates[self._rr % len(templates)]
        self._rr += 1
        group: list[JoinRequest] = []
        rest: deque[JoinRequest] = deque()
        while self.queue:
            r = self.queue.popleft()
            if r.template == chosen and len(group) < self.slots:
                group.append(r)
            else:
                rest.append(r)
        self.queue = rest
        self._serve_group(chosen, group)
        return group

    def run(self, max_steps: int = 10_000) -> list[JoinRequest]:
        """Drain the queue; returns every retired request in retire order."""
        out: list[JoinRequest] = []
        steps = 0
        while self.queue and steps < max_steps:
            out.extend(self.step())
            steps += 1
        return out

    # ---- internals ----------------------------------------------------
    def _reject(self, req: JoinRequest, err: Exception) -> None:
        req.error = err
        req.done = True

    def _observe_cost(self, key, dt_us: float) -> None:
        ema = self.cost_ema_us.get(key)
        self.cost_ema_us[key] = (
            dt_us if ema is None else (1 - self.ema_alpha) * ema + self.ema_alpha * dt_us
        )

    def _reap_deadlines(self, reqs: list[JoinRequest]) -> None:
        """Reject (reason "deadline") every live request past its
        submit-relative deadline — called before each dispatch round, so a
        request stuck behind a slow neighbor is refused, not served late."""
        now = time.perf_counter()
        for r in reqs:
            if r.done or r.deadline_ms is None:
                continue
            waited_ms = (now - r.t_submit) * 1e3
            if waited_ms > r.deadline_ms:
                self.deadline_rejected += 1
                self.admission.reject_runtime(r.tenant, reason="deadline")
                self._reject(
                    r,
                    AdmissionError(
                        f"deadline {r.deadline_ms:.0f}ms exceeded "
                        f"({waited_ms:.0f}ms queued)",
                        tenant=r.tenant,
                        reason="deadline",
                    ),
                )

    def _backoff(self, evictions: int) -> None:
        """Jittered exponential backoff between quota-eviction rounds: an
        overflow storm re-dispatches at a decaying rate instead of
        hot-looping the host. Deterministically seeded; set
        backoff_base_ms=0 to disable."""
        if self.backoff_base_ms <= 0:
            return
        delay = min(self.backoff_cap_ms, self.backoff_base_ms * (2 ** (evictions - 1)))
        delay *= 1.0 + self.backoff_jitter * self._jitter_rng.random()
        time.sleep(delay / 1e3)

    def _acquire(self, t: PlanTemplate, *, batch, group):
        runner, rels, _, _ = _acquire_runner(
            t.query,
            t.relations,
            t.plan_tree,
            agg=t.agg,
            options=t.options,
            filter_vars=t.filter_vars,
            batch=batch,
            max_capacity=self._group_capacity_quota(group),
            cache=self._cache,
        )
        return runner, rels

    def _admit(self, t: PlanTemplate, group, cells: int) -> list[JoinRequest]:
        """Pre-compile admission: measured cost first (a cost rejection
        must not count as admitted), then the planned-cells check — the
        capacity plan exists, the executor does not yet, so either
        violation costs zero XLA work."""
        live: list[JoinRequest] = []
        ema = self.cost_ema_us.get(t.key)
        for req in group:
            try:
                self.admission.check_cost(req.tenant, ema)
                self.admission.check_plan(req.tenant, cells)
            except AdmissionError as e:
                self._reject(req, e)
            else:
                live.append(req)
        return live

    def _serve_group(self, template: PlanTemplate, group: list[JoinRequest]) -> None:
        t = template
        self._reap_deadlines(group)
        group = [r for r in group if not r.done]
        if not group:
            return
        live: list[JoinRequest] | None = None
        try:
            batch = self.slots if t.filter_vars else None
            runner, rels = self._acquire(t, batch=batch, group=group)
            live = self._admit(t, group, runner.cap_plan.cells())
            if not live:
                return
            if not t.filter_vars:
                self._dispatch_filterless(t, runner, rels, live)
            else:
                self._dispatch_batched(t, runner, rels, live, self.slots)
        except Exception as e:
            if not faults.recoverable(e):
                raise
            pending = [r for r in (group if live is None else live) if not r.done]
            if live is None:
                # the fault struck before admission (acquire/compile): the
                # cells check needs a capacity plan that never materialized,
                # so admit on the cost quota alone before degrading
                pending = self._admit(t, pending, 0)
            self.faults_absorbed += 1
            self._degrade(t, pending, e)

    def _dispatch_filterless(self, t, runner, rels, live) -> None:
        # nothing varies per lane: one unbatched call answers everyone
        t0 = time.perf_counter()
        out = runner.run_relations(rels, reuse_tries=True)
        self._observe_cost(t.key, (time.perf_counter() - t0) * 1e6)
        self.dispatches += 1
        for req in live:
            req.result, req.done = out, True
            self.served += 1

    def _dispatch_batched(self, t, runner, rels, live, width: int, label=None) -> None:
        """Serve `live` in chunks of `width` lanes (one vmapped dispatch
        each). CapacityQuotaError evicts the named lane, charges the
        OFFENDER's retry budget, backs off, and re-dispatches the rest
        against the same compiled executor; the pending set strictly
        shrinks every round, so the loop terminates structurally."""
        evictions = 0
        evicted_by: dict[str, int] = {}
        pending = [r for r in live if not r.done]
        while pending:
            self._reap_deadlines(pending)
            pending = [r for r in pending if not r.done]
            if not pending:
                return
            lanes = pending[:width]
            consts = np.broadcast_to(lanes[0].consts, (width, len(t.filter_vars))).copy()
            for i, req in enumerate(lanes):
                consts[i] = req.consts  # dead slots keep lane 0's constants
            t0 = time.perf_counter()
            try:
                out = runner.run_relations(rels, reuse_tries=True, filter_consts=consts)
            except CapacityQuotaError as e:
                self._observe_cost(t.key, (time.perf_counter() - t0) * 1e6)
                self.dispatches += 1
                victim = (
                    lanes[e.lane]
                    if e.lane is not None and e.lane < len(lanes)
                    else lanes[0]
                )
                self.admission.reject_runtime(victim.tenant)
                self._reject(victim, e)
                pending.remove(victim)
                # the retry budget is the offender's: its max_retries bounds
                # how many eviction rounds ITS lanes may cause in this
                # group; past that, its remaining requests go wholesale
                n = evicted_by.get(victim.tenant, 0) + 1
                evicted_by[victim.tenant] = n
                if n > self.admission.quota(victim.tenant).max_retries:
                    for r in [p for p in pending if p.tenant == victim.tenant]:
                        self.admission.reject_runtime(r.tenant, reason="retries")
                        self._reject(
                            r,
                            AdmissionError(
                                "retry budget exhausted by repeated quota "
                                "evictions",
                                tenant=r.tenant,
                                reason="retries",
                            ),
                        )
                        pending.remove(r)
                evictions += 1
                self._backoff(evictions)
                continue
            self._observe_cost(t.key, (time.perf_counter() - t0) * 1e6)
            self.dispatches += 1
            for i, req in enumerate(lanes):
                req.result = int(out[i]) if t.agg == "count" else out[i]
                req.done = True
                req.degraded_to = label
                self.served += 1
                if label is not None:
                    self.degraded[label] += 1
            pending = [r for r in pending if not r.done]

    def _degrade(self, t, pending: list[JoinRequest], cause: Exception) -> None:
        """Walk the remaining ladder rungs for requests a recoverable fault
        left unserved: halved batch width (a fresh, narrower compile) ->
        unbatched kill-mode -> eager host fallback. The eager rung cannot
        fail for device reasons, so every request completes."""
        half = self.slots // 2
        if t.filter_vars and half >= 1 and pending:
            try:
                runner, rels = self._acquire(t, batch=half, group=pending)
                self._dispatch_batched(t, runner, rels, pending, half, label="halved")
            except Exception as e:
                if not faults.recoverable(e):
                    raise
                self.faults_absorbed += 1
            pending = [r for r in pending if not r.done]
        if t.filter_vars and pending:
            try:
                runner, rels = self._acquire(t, batch=None, group=pending)
                for req in list(pending):
                    if req.done:
                        continue
                    t0 = time.perf_counter()
                    try:
                        out = runner.run_relations(
                            rels, reuse_tries=True, filter_consts=req.consts
                        )
                    except CapacityQuotaError as e:
                        self.admission.reject_runtime(req.tenant)
                        self._reject(req, e)
                        continue
                    self._observe_cost(t.key, (time.perf_counter() - t0) * 1e6)
                    self.dispatches += 1
                    req.result = int(out) if t.agg == "count" else out
                    req.done = True
                    req.degraded_to = "unbatched"
                    self.served += 1
                    self.degraded["unbatched"] += 1
            except Exception as e:
                if not faults.recoverable(e):
                    raise
                self.faults_absorbed += 1
            pending = [r for r in pending if not r.done]
        for req in pending:
            if not req.done:
                self._serve_eager(t, req)

    def _serve_eager(self, t, req: JoinRequest) -> None:
        """Ladder bottom: answer one request on the eager host engine over
        live-row snapshots. agg=None results follow the eager contract
        ((bound, mult)) rather than the compiled one."""
        filters = {v: int(c) for v, c in zip(t.filter_vars, req.consts)}
        tree = t.plan_tree if isinstance(t.plan_tree, BinaryPlan) else None
        rels = {a: relcache.live_relation(r) for a, r in t.relations.items()}
        out = free_join(t.query, rels, tree, agg=t.agg, filters=filters or None)
        req.result = int(out) if t.agg == "count" else out
        req.done = True
        req.degraded_to = "eager"
        self.served += 1
        self.degraded["eager"] += 1

    def _group_capacity_quota(self, group: list[JoinRequest]) -> int | None:
        """The runtime growth quota armed on the group's runner: the max of
        the members' per-node capacity quotas (the loosest bound — a raise
        still names the offending lane, and tighter per-tenant bounds are
        re-checked against the violation's need on eviction). None if no
        member carries one."""
        caps = [
            q.max_node_capacity
            for q in (self.admission.quota(r.tenant) for r in group)
            if q.max_node_capacity is not None
        ]
        return max(caps) if caps else None
