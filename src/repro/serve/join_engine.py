"""Multi-tenant join-query serving over the compiled Free Join path.

The decode engine next door (engine.py) serves token streams; this engine
serves *queries*. Same TPU discipline, different payload: fixed-width
request slots so the compiled executor never changes shape, an occupancy
mask instead of a varying batch, and a host control plane that admits,
groups, dispatches, and retires.

The pipeline per `step()`:

1. **Pick a template, round-robin.** Every submitted request was
   canonicalized on arrival (templates.canonicalize): alpha-renamed
   aliases, constants lifted out. Requests sharing a template key —
   however differently their tenants spelled the query — are batchable
   against ONE compiled runner. Each step serves the *next* queued
   template in rotation (not the head-of-line one): a tenant streaming
   requests on one template can fill the queue front forever, and
   first-template-wins would starve every other template behind it.
2. **Admit.** The runner's capacity plan is known before any compile;
   each request is checked against its tenant's measured-cost quota
   (`max_dispatch_us` vs the template's dispatch-time EMA — see below)
   and its `max_plan_cells` quota, and rejected with zero XLA work on
   violation.
3. **Dispatch one vmapped probe.** Up to `slots` co-template requests run
   as one batched executor call over the shared cached tries: the int32
   constants matrix (slots, F) is the only per-lane input. Dead slots
   are padded with a live lane's constants (they compute a duplicate
   answer that is simply not read back).
4. **Evict on quota.** If the adaptive runner raises CapacityQuotaError,
   the named lane's request is rejected, its slot re-padded, and the
   remaining requests re-dispatched against the same compiled executor —
   co-batched tenants never pay a recompile for a pathological neighbor.

Filterless templates (F=0) have nothing to vary per lane, so the whole
group is served by ONE unbatched call whose result every member shares —
degenerate batching, and the cheapest possible kind.

The engine also keeps a per-template exponential moving average of
measured dispatch wall time (`cost_ema_us`, updated on every dispatch —
cold compiles included, decayed by later warm dispatches). Admission
consults it alongside the planned cells: planning says what a template
*should* cost, the EMA says what it *did* cost last time(s).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import numpy as np

from repro.core import api
from repro.core.api import ExecOptions, _acquire_runner
from repro.core.capacity import CapacityQuotaError
from repro.relational.relation import Relation
from repro.relational.schema import Query
from repro.serve.admission import AdmissionController, AdmissionError
from repro.serve.templates import PlanTemplate, canonicalize


@dataclasses.dataclass
class JoinRequest:
    rid: int
    tenant: str
    template: PlanTemplate | None  # None: rejected at submit-time verification
    consts: np.ndarray  # (F,) int32 — the lifted selection constants
    result: object = None
    error: Exception | None = None
    done: bool = False


class JoinServeEngine:
    """Concurrent join serving: submit() canonicalizes, step() batches.

    slots: fixed dispatch width — every batched runner is compiled at this
    width once and reused for any group size up to it. options: compiled-
    path ExecOptions shared by all templates this engine builds (a request
    may still carry its own via canonicalize). admission: quota controller
    (default: no quotas). The engine keys its runners in a scoped
    namespace of the process runner cache, so template-canonicalized keys
    can never collide with compiled_free_join's verbatim keys."""

    def __init__(
        self,
        *,
        slots: int = 8,
        options: ExecOptions | None = None,
        admission: AdmissionController | None = None,
        cache=None,
    ):
        if slots < 1:
            raise ValueError("slots must be >= 1")
        self.slots = slots
        self.options = options or ExecOptions()
        self.admission = admission or AdmissionController()
        self._cache = (cache if cache is not None else api._runner_cache).scoped("join-templates")
        self.queue: deque[JoinRequest] = deque()
        self._next_rid = 0
        self._rr = 0  # round-robin cursor over queued templates
        self.dispatches = 0  # batched executor calls issued
        self.served = 0  # requests completed successfully
        # template key -> EMA of measured dispatch wall time (us). Bounded
        # in practice by the runner cache's LRU (dead templates stop being
        # re-submitted); alpha 0.3 forgets a cold compile in a few warm
        # dispatches.
        self.cost_ema_us: dict = {}
        self.ema_alpha = 0.3

    # ---- intake -------------------------------------------------------
    def submit(
        self,
        query: Query,
        relations: dict[str, Relation],
        filters: dict[str, int] | None = None,
        *,
        tenant: str = "default",
        agg: str | None = "count",
        plan_tree=None,
    ) -> JoinRequest:
        """Canonicalize, statically verify, and enqueue one query; returns
        its JoinRequest handle (result/error/done are filled by step()).

        Verification failures REJECT the request (error set, done=True,
        admission counter bumped) instead of raising or enqueuing: a raise
        would crash the submitting tenant's whole intake loop, and an
        enqueued invalid plan would detonate mid-dispatch inside a batch
        shared with innocent co-template tenants. A rejected handle comes
        back immediately and never touches the serving loop."""
        from repro.analysis.diagnostics import PlanVerificationError
        from repro.analysis.planlint import lint_query, lint_template, lint_tree

        # the ORIGINAL query, pre-canonicalization: canonicalize silently
        # drops head vars no atom binds, so the template would look clean
        rep = lint_query(query)
        rep.extend(lint_tree(query, plan_tree)[0])
        try:
            rep.raise_errors()
            template, consts = canonicalize(
                query, relations, filters, plan_tree=plan_tree, agg=agg,
                options=self.options,
            )
            lint_template(template).raise_errors()
        except (PlanVerificationError, ValueError) as e:
            req = JoinRequest(
                rid=self._next_rid, tenant=tenant,
                template=None, consts=np.zeros(0, np.int32),  # type: ignore[arg-type]
            )
            self._next_rid += 1
            self.admission.reject_runtime(tenant)
            self._reject(req, e)
            return req
        req = JoinRequest(rid=self._next_rid, tenant=tenant, template=template, consts=consts)
        self._next_rid += 1
        self.queue.append(req)
        return req

    # ---- serving loop -------------------------------------------------
    def step(self) -> list[JoinRequest]:
        """One engine iteration: pick the next queued template in round-robin
        rotation, pull every queued co-template request into up to `slots`
        lanes, and serve them with one dispatch. Returns the requests retired
        this step (completed or rejected).

        Rotation, not head-of-line: with first-template-wins, a tenant
        streaming requests on one template keeps the queue front occupied
        and every other template waits forever. The rotation cursor walks
        the arrival-ordered list of *distinct* queued templates, so k live
        templates each get every k-th dispatch regardless of queue depth."""
        if not self.queue:
            return []
        templates: list[PlanTemplate] = []
        for r in self.queue:
            if r.template not in templates:
                templates.append(r.template)
        chosen = templates[self._rr % len(templates)]
        self._rr += 1
        group: list[JoinRequest] = []
        rest: deque[JoinRequest] = deque()
        while self.queue:
            r = self.queue.popleft()
            if r.template == chosen and len(group) < self.slots:
                group.append(r)
            else:
                rest.append(r)
        self.queue = rest
        self._serve_group(chosen, group)
        return group

    def run(self, max_steps: int = 10_000) -> list[JoinRequest]:
        """Drain the queue; returns every retired request in retire order."""
        out: list[JoinRequest] = []
        steps = 0
        while self.queue and steps < max_steps:
            out.extend(self.step())
            steps += 1
        return out

    # ---- internals ----------------------------------------------------
    def _reject(self, req: JoinRequest, err: Exception) -> None:
        req.error = err
        req.done = True

    def _observe_cost(self, key, dt_us: float) -> None:
        ema = self.cost_ema_us.get(key)
        self.cost_ema_us[key] = (
            dt_us if ema is None else (1 - self.ema_alpha) * ema + self.ema_alpha * dt_us
        )

    def _serve_group(self, template: PlanTemplate, group: list[JoinRequest]) -> None:
        t = template
        batch = self.slots if t.filter_vars else None
        runner, rels, _, _ = _acquire_runner(
            t.query,
            t.relations,
            t.plan_tree,
            agg=t.agg,
            options=t.options,
            filter_vars=t.filter_vars,
            batch=batch,
            max_capacity=self._group_capacity_quota(group),
            cache=self._cache,
        )
        # pre-compile admission: measured cost first (a cost rejection must
        # not count as admitted), then the planned-cells check — the
        # capacity plan exists, the executor does not yet, so either
        # violation costs zero XLA work
        live: list[JoinRequest] = []
        cells = runner.cap_plan.cells()
        ema = self.cost_ema_us.get(t.key)
        for req in group:
            try:
                self.admission.check_cost(req.tenant, ema)
                self.admission.check_plan(req.tenant, cells)
            except AdmissionError as e:
                self._reject(req, e)
            else:
                live.append(req)
        if not live:
            return
        if not t.filter_vars:
            # nothing varies per lane: one unbatched call answers everyone
            t0 = time.perf_counter()
            out = runner.run_relations(rels, reuse_tries=True)
            self._observe_cost(t.key, (time.perf_counter() - t0) * 1e6)
            self.dispatches += 1
            for req in live:
                req.result, req.done = out, True
                self.served += 1
            return
        retries = max(self.admission.quota(r.tenant).max_retries for r in live)
        for _round in range(retries + 1):
            consts = np.broadcast_to(live[0].consts, (self.slots, len(t.filter_vars))).copy()
            for i, req in enumerate(live):
                consts[i] = req.consts  # dead slots keep lane 0's constants
            t0 = time.perf_counter()
            try:
                out = runner.run_relations(rels, reuse_tries=True, filter_consts=consts)
            except CapacityQuotaError as e:
                self._observe_cost(t.key, (time.perf_counter() - t0) * 1e6)
                self.dispatches += 1
                victim = live[e.lane] if e.lane is not None and e.lane < len(live) else live[0]
                self.admission.reject_runtime(victim.tenant)
                self._reject(victim, e)
                live = [r for r in live if r is not victim]
                if not live:
                    return
                continue
            self._observe_cost(t.key, (time.perf_counter() - t0) * 1e6)
            self.dispatches += 1
            for i, req in enumerate(live):
                req.result = int(out[i]) if t.agg == "count" else out[i]
                req.done = True
                self.served += 1
            return
        # retry budget exhausted: reject whatever is still unserved
        for req in live:
            self.admission.reject_runtime(req.tenant)
            self._reject(
                req,
                AdmissionError(
                    "retry budget exhausted for batched dispatch",
                    tenant=req.tenant,
                    reason="retries",
                ),
            )

    def _group_capacity_quota(self, group: list[JoinRequest]) -> int | None:
        """The runtime growth quota armed on the group's runner: the max of
        the members' per-node capacity quotas (the loosest bound — a raise
        still names the offending lane, and tighter per-tenant bounds are
        re-checked against the violation's need on eviction). None if no
        member carries one."""
        caps = [
            q.max_node_capacity
            for q in (self.admission.quota(r.tenant) for r in group)
            if q.max_node_capacity is not None
        ]
        return max(caps) if caps else None
