"""Admission control for the join serving engine.

A multi-tenant engine's worst failure mode is not a slow query — it is a
query whose frontier buffers blow past their planned capacities, because
recovery (grow + recompile + re-run) stalls every co-batched request
behind one tenant's pathology. Admission control converts that stall into
a bounded, attributable rejection, at four layers:

1. **pre-compile** (`max_plan_cells`): the capacity planner's total
   buffer-cell count is known before the executor ever compiles, so an
   oversized template is rejected with zero XLA work.
1b. **measured cost** (`max_dispatch_us`): the engine keeps a per-template
   EMA of measured dispatch wall time; a template that has *demonstrated*
   it costs more than the tenant's budget is rejected up front, even when
   its planned footprint looked innocent (planned cells can't see probe
   rounds, retry storms, or host overheads — the measurement can).
2. **runtime growth quota** (`max_node_capacity`): the adaptive runner
   refuses to grow any single node past this bound, raising
   `core.capacity.CapacityQuotaError` naming the offending batch lane —
   the engine evicts that one request and re-dispatches the rest against
   the *existing* compiled executor (no recompile).
3. **retry budget** (`max_retries`): eviction rounds are charged to the
   tenant that caused them — once a tenant's evictions in one group
   exceed its own max_retries, its remaining queued requests are
   rejected wholesale. Compliant co-batched tenants never pay: each
   eviction strictly shrinks the batch, so the dispatch loop terminates
   without ever spending an innocent tenant's budget.

Quotas are per-tenant (`AdmissionController.quota`), falling back to a
default; counters (`admitted`/`rejected`, and the per-tenant
`rejected_by`/`rejected_reasons` breakdowns) are the observable contract
the serving tests and benchmark lock.
"""
from __future__ import annotations

from dataclasses import dataclass


class AdmissionError(RuntimeError):
    """A request was refused by admission control (quota violation)."""

    def __init__(self, msg: str, *, tenant: str = "default", reason: str = "quota"):
        super().__init__(msg)
        self.tenant = tenant
        self.reason = reason


@dataclass(frozen=True)
class QueryQuota:
    """Per-query resource quota. None disables a bound.

    max_plan_cells: ceiling on the capacity plan's total buffer cells
    (sum of per-node capacities across all stages) — checked before the
    first compile. max_node_capacity: ceiling any single frontier buffer
    may grow to at runtime (armed inside the adaptive runner). max_retries:
    quota-eviction rounds allowed per batched dispatch.
    max_dispatch_us: ceiling on the template's *measured* dispatch time
    (the engine's per-template EMA, microseconds) — planned cells say what
    a query should cost, the EMA says what it actually costs, and a
    template whose measured cost blew past the quota is rejected before
    joining another batch. A template's first-ever dispatch has no EMA and
    is admitted on the planned-cost checks alone."""

    max_plan_cells: int | None = None
    max_node_capacity: int | None = None
    max_retries: int = 3
    max_dispatch_us: float | None = None


class AdmissionController:
    """Per-tenant quota book-keeping: `quota(tenant)` resolves the
    effective QueryQuota, `check_plan(...)` performs the pre-compile cells
    test, and admitted/rejected count every decision."""

    def __init__(
        self,
        default: QueryQuota | None = None,
        per_tenant: dict[str, QueryQuota] | None = None,
    ):
        self.default = default or QueryQuota()
        self.per_tenant = dict(per_tenant or {})
        self.admitted = 0
        self.rejected = 0
        # attribution: which tenant was rejected, and why — the isolation
        # tests assert an eviction storm charges only its offender
        self.rejected_by: dict[str, int] = {}
        self.rejected_reasons: dict[str, int] = {}

    def quota(self, tenant: str) -> QueryQuota:
        return self.per_tenant.get(tenant, self.default)

    def _count_reject(self, tenant: str, reason: str) -> None:
        self.rejected += 1
        self.rejected_by[tenant] = self.rejected_by.get(tenant, 0) + 1
        self.rejected_reasons[reason] = self.rejected_reasons.get(reason, 0) + 1

    def check_plan(self, tenant: str, plan_cells: int) -> None:
        """Pre-compile admission: reject if the planned buffer footprint
        exceeds the tenant's cells quota. Raises AdmissionError (and counts
        the rejection); otherwise counts an admission."""
        q = self.quota(tenant)
        if q.max_plan_cells is not None and plan_cells > q.max_plan_cells:
            self._count_reject(tenant, "plan_cells")
            raise AdmissionError(
                f"plan footprint {plan_cells} cells exceeds tenant {tenant!r} "
                f"quota of {q.max_plan_cells}",
                tenant=tenant,
                reason="plan_cells",
            )
        self.admitted += 1

    def check_cost(self, tenant: str, measured_us: float | None) -> None:
        """Measured-cost admission: reject when the template's measured
        dispatch-time EMA exceeds the tenant's quota. Called BEFORE
        check_plan (a cost rejection must not count as admitted);
        measured_us=None (template never dispatched) always passes."""
        q = self.quota(tenant)
        if (
            q.max_dispatch_us is not None
            and measured_us is not None
            and measured_us > q.max_dispatch_us
        ):
            self._count_reject(tenant, "measured_cost")
            raise AdmissionError(
                f"measured dispatch cost {measured_us:.0f}us exceeds tenant "
                f"{tenant!r} quota of {q.max_dispatch_us:.0f}us",
                tenant=tenant,
                reason="measured_cost",
            )

    def reject_runtime(self, tenant: str, reason: str = "quota") -> None:
        """Count a runtime rejection — a growth-quota eviction (the raise
        site is the adaptive runner; the engine calls this when it evicts
        the lane), an exhausted retry budget, or a missed deadline."""
        self._count_reject(tenant, reason)
