"""Continuous-batching *decode* serve engine (the LLM stack — join-query
serving lives in repro.serve.join_engine).

Fixed-width decode slots (static shapes for jit) + host control plane:
admit requests into free slots (prefill writes their KV), decode all active
slots in one batched decode_step with per-slot cur_len, retire finished
sequences and refill. This is the standard TPU serving shape discipline —
the batch never changes shape, only the slot occupancy mask does.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import ModelConfig, decode_step, init_cache
from repro.serve.paged_kv import PagedAllocator


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class DecodeServeEngine:
    def __init__(self, params, cfg: ModelConfig, *, slots: int, max_len: int, greedy: bool = True):
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.cache = init_cache(cfg, slots, max_len)
        self.cur_len = np.zeros(slots, np.int32)
        self.active: list[Request | None] = [None] * slots
        self.queue: deque[Request] = deque()
        self.pages = PagedAllocator(num_pages=slots * (max_len // 16 + 1), page_size=16)
        self._decode = jax.jit(
            lambda p, tok, cache, cur: decode_step(p, cfg, tok, cache, cur)
        )
        self._next_tok = np.zeros((slots, 1), np.int32)
        self.greedy = greedy
        self.steps = 0

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.popleft()
                self.active[s] = req
                self.pages.alloc(req.rid, len(req.prompt))
                self._prefill(s, req)

    def _prefill(self, slot: int, req: Request) -> None:
        """Prefill by teacher-forcing the prompt through decode steps for
        the single slot (simple and exact; a production path would use the
        full-sequence forward + cache scatter)."""
        for tok in req.prompt:
            self._next_tok[slot, 0] = tok
            cur = jnp.asarray(self.cur_len)
            logits, self.cache = self._decode(
                self.params, jnp.asarray(self._next_tok), self.cache, cur
            )
            self.cur_len[slot] += 1
        nxt = int(jnp.argmax(logits[slot, -1]))
        self._next_tok[slot, 0] = nxt
        req.out.append(nxt)

    def step(self) -> int:
        """One engine iteration: admit + one batched decode. Returns the
        number of active sequences."""
        self._admit()
        if not any(self.active):
            return 0
        cur = jnp.asarray(self.cur_len)
        logits, self.cache = self._decode(
            self.params, jnp.asarray(self._next_tok), self.cache, cur
        )
        toks = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
        self.steps += 1
        n_active = 0
        for s, req in enumerate(self.active):
            if req is None:
                continue
            self.cur_len[s] += 1
            self.pages.alloc(req.rid, int(self.cur_len[s]) + 1)
            req.out.append(int(toks[s]))
            self._next_tok[s, 0] = toks[s]
            if len(req.out) >= req.max_new or self.cur_len[s] >= self.max_len - 1:
                req.done = True
                self.pages.release(req.rid)
                self.active[s] = None
                self.cur_len[s] = 0
            else:
                n_active += 1
        return n_active + len(self.queue)

    def run(self, max_steps: int = 10_000) -> None:
        while (self.queue or any(self.active)) and self.steps < max_steps:
            self.step()


# the pre-rename public name; kept one release so external callers keep
# importing while the join engine takes over the generic "serving" slot
ServeEngine = DecodeServeEngine
