"""Serving: two engines, one shape discipline.

* **DecodeServeEngine** (engine.py) serves *model decode*: continuous
  batching of LLM requests into fixed decode slots with a paged KV cache.
  `ServeEngine` remains as a deprecated alias of this class.
* **JoinServeEngine** (join_engine.py) serves *join queries*: concurrent
  tenants' queries are canonicalized into plan templates
  (templates.canonicalize — alias alpha-renaming + constant lifting),
  co-template requests are dispatched as one vmapped probe over shared
  cached tries, and admission control (admission.py) rejects
  quota-violating queries instead of letting them trigger grow/recompile
  storms. See serve/README.md for the quota knobs.
* **StandingQueryEngine** (standing.py) keeps registered join queries
  *answered* as base relations mutate through the relcache delta API: each
  refresh recomputes only the plan stages whose input fingerprints moved
  (delta-merged tries from the versioned trie cache), replaying cached
  device buffers for the rest.

The engines keep the batch shape static and vary only occupancy — the
TPU serving discipline the rest of the repo compiles against.
"""
from repro.serve.admission import AdmissionController, AdmissionError, QueryQuota
from repro.serve.engine import DecodeServeEngine, Request, ServeEngine
from repro.serve.join_engine import JoinRequest, JoinServeEngine
from repro.serve.paged_kv import PagedAllocator
from repro.serve.standing import StandingQuery, StandingQueryEngine
from repro.serve.templates import PlanTemplate, canonicalize

__all__ = [
    "AdmissionController",
    "AdmissionError",
    "DecodeServeEngine",
    "JoinRequest",
    "JoinServeEngine",
    "PagedAllocator",
    "PlanTemplate",
    "QueryQuota",
    "Request",
    "ServeEngine",
    "StandingQuery",
    "StandingQueryEngine",
    "canonicalize",
]
