from repro.serve.engine import Request, ServeEngine
from repro.serve.paged_kv import PagedAllocator

__all__ = ["Request", "ServeEngine", "PagedAllocator"]
