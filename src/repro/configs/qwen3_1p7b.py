"""qwen3-1.7b [dense]: 28L d=2048 16H (GQA kv=8) d_ff=6144 vocab=151936.

qk_norm on per-head q/k, head_dim=128, GQA [hf:Qwen/Qwen3-8B family]."""
from repro.configs.common import ArchSpec
from repro.models.transformer import ModelConfig

_FULL = ModelConfig(
    name="qwen3-1.7b",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=6144,
    vocab=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1e6,
    act="swiglu",
    tie_embeddings=True,
)

_REDUCED = ModelConfig(
    name="qwen3-reduced",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab=128,
    head_dim=32,
    qk_norm=True,
    act="swiglu",
    tie_embeddings=True,
    compute_dtype="float32",
)


def spec() -> ArchSpec:
    return ArchSpec(model=_FULL, reduced=_REDUCED,
                    notes="full attention: long_500k N/A")
