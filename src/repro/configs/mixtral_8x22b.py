"""mixtral-8x22b [moe]: 56L d=6144 48H (GQA kv=8) d_ff=16384 vocab=32768,
MoE 8 experts top-2, sliding-window attention [arXiv:2401.04088].

SWA window 4096 => the decode KV cache is a rotating 4k buffer, making
long_500k eligible (sub-quadratic in context length).
"""
from repro.configs.common import ArchSpec
from repro.models.moe import MoEConfig
from repro.models.transformer import ModelConfig

_FULL = ModelConfig(
    name="mixtral-8x22b",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab=32768,
    head_dim=128,
    act="swiglu",
    sliding_window=4096,
    tie_embeddings=False,
    param_dtype="bfloat16",
    moe=MoEConfig(num_experts=8, top_k=2, d_ff=16384, every_n=1),
)

_REDUCED = ModelConfig(
    name="mixtral-reduced",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab=128,
    sliding_window=8,
    act="swiglu",
    tie_embeddings=False,
    compute_dtype="float32",
    moe=MoEConfig(num_experts=4, top_k=2, d_ff=128, every_n=1),
)


def spec() -> ArchSpec:
    return ArchSpec(model=_FULL, reduced=_REDUCED, long_context_ok=True,
                    notes="SWA => long_500k runs with a 4k rotating KV buffer")
