"""Arch spec plumbing shared by all 10 assigned architecture configs.

Each config module exposes `spec() -> ArchSpec`. The full ModelConfig is
exercised only via the dry-run (ShapeDtypeStruct, no allocation); smoke
tests instantiate `reduced()`.

Shapes (assigned, LM family — seq_len x global_batch):
  train_4k     4,096 x 256   train_step
  prefill_32k  32,768 x 32   serve prefill (full-sequence forward)
  decode_32k   32,768 x 128  serve decode (1 new token, KV cache = seq_len)
  long_500k    524,288 x 1   long-context decode; sub-quadratic archs only
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.transformer import ModelConfig

SHAPES: dict[str, tuple[int, int, str]] = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    model: ModelConfig
    reduced: ModelConfig
    opt_dtype: str = "float32"  # Adam moment dtype (bf16 for the >=398B archs)
    modality: str = "text"  # text | vlm | audio (stub frontends)
    long_context_ok: bool = False  # sub-quadratic => long_500k eligible
    notes: str = ""

    def shape_supported(self, shape: str) -> bool:
        if shape == "long_500k":
            return self.long_context_ok
        return shape in SHAPES

    def _input_struct(self, batch: int, seq: int) -> jax.ShapeDtypeStruct:
        if self.modality == "text":
            return jax.ShapeDtypeStruct((batch, seq), jnp.int32)
        # stub (non-text) frontend: precomputed patch/frame embeddings
        return jax.ShapeDtypeStruct((batch, seq, self.model.d_model), jnp.bfloat16)

    def input_specs(self, shape: str) -> dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for every model input of `shape`
        (weak-type-correct, shardable, no device allocation)."""
        seq, batch, kind = SHAPES[shape]
        if kind == "train":
            return {
                "inputs": self._input_struct(batch, seq),
                "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
            }
        if kind == "prefill":
            return {"inputs": self._input_struct(batch, seq)}
        # decode: one new token against a KV cache of length seq
        inp = self._input_struct(batch, 1)
        return {
            "inputs": inp,
            "cur_len": jax.ShapeDtypeStruct((), jnp.int32),
            # cache specs are derived by launch/dryrun.py via
            # jax.eval_shape(init_cache, ...) with (batch, seq)
        }
