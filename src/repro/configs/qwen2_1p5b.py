"""qwen2-1.5b [dense]: 28L d=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.

GQA + QKV bias [arXiv:2407.10671]."""
from repro.configs.common import ArchSpec
from repro.models.transformer import ModelConfig

_FULL = ModelConfig(
    name="qwen2-1.5b",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1e6,
    act="swiglu",
    tie_embeddings=True,
)

_REDUCED = ModelConfig(
    name="qwen2-reduced",
    num_layers=2,
    d_model=48,
    num_heads=4,
    num_kv_heads=2,
    d_ff=96,
    vocab=128,
    head_dim=16,
    qkv_bias=True,
    act="swiglu",
    tie_embeddings=True,
    compute_dtype="float32",
)


def spec() -> ArchSpec:
    return ArchSpec(model=_FULL, reduced=_REDUCED,
                    notes="full attention: long_500k N/A")
