"""gemma-2b [dense]: 18L d=2048 8H (MQA kv=1) d_ff=16384 vocab=256000.

GeGLU, head_dim=256 (wider than d_model/heads), MQA [arXiv:2403.08295]."""
from repro.configs.common import ArchSpec
from repro.models.transformer import ModelConfig

_FULL = ModelConfig(
    name="gemma-2b",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    d_ff=16384,
    vocab=256000,
    head_dim=256,
    act="geglu",
    tie_embeddings=True,
)

_REDUCED = ModelConfig(
    name="gemma-reduced",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    d_ff=256,
    vocab=256,
    head_dim=32,
    act="geglu",
    tie_embeddings=True,
    compute_dtype="float32",
)


def spec() -> ArchSpec:
    return ArchSpec(model=_FULL, reduced=_REDUCED,
                    notes="full attention: long_500k N/A")
