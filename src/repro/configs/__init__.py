"""Registry of the 10 assigned architectures (--arch <id>)."""
from repro.configs.common import SHAPES, ArchSpec

ARCHS = {
    "phi-3-vision-4.2b": "repro.configs.phi3_vision_4p2b",
    "musicgen-large": "repro.configs.musicgen_large",
    "qwen3-1.7b": "repro.configs.qwen3_1p7b",
    "gemma-2b": "repro.configs.gemma_2b",
    "codeqwen1.5-7b": "repro.configs.codeqwen1p5_7b",
    "qwen2-1.5b": "repro.configs.qwen2_1p5b",
    "arctic-480b": "repro.configs.arctic_480b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "jamba-1.5-large-398b": "repro.configs.jamba_1p5_large_398b",
    "rwkv6-1.6b": "repro.configs.rwkv6_1p6b",
}


def get_arch(name: str) -> ArchSpec:
    import importlib

    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return importlib.import_module(ARCHS[name]).spec()


__all__ = ["ARCHS", "SHAPES", "ArchSpec", "get_arch"]
