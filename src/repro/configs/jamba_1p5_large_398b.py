"""jamba-1.5-large-398b [hybrid]: 72L d=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16 experts top-2, Mamba+attention 1:7 interleave
[arXiv:2403.19887].

Pattern unit = 8 layers (1 attn + 7 mamba), scanned 9x. MoE every other
layer. Hybrid => long_500k eligible (only 9 attention layers hold KV;
mamba layers carry O(1) state). bf16 params + bf16 moments at 398B.
"""
from repro.configs.common import ArchSpec
from repro.models.moe import MoEConfig
from repro.models.transformer import ModelConfig

_FULL = ModelConfig(
    name="jamba-1.5-large-398b",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    head_dim=128,
    act="swiglu",
    tie_embeddings=False,
    param_dtype="bfloat16",
    block_pattern=("attn",) + ("mamba",) * 7,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff=24576, every_n=2),
)

_REDUCED = ModelConfig(
    name="jamba-reduced",
    num_layers=8,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab=128,
    act="swiglu",
    tie_embeddings=False,
    compute_dtype="float32",
    block_pattern=("attn",) + ("mamba",) * 3,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff=128, every_n=2),
)


def spec() -> ArchSpec:
    return ArchSpec(model=_FULL, reduced=_REDUCED, opt_dtype="bfloat16",
                    long_context_ok=True,
                    notes="hybrid: 9 attn layers w/ KV, 63 mamba layers O(1) state")
