"""arctic-480b [moe]: 35L d=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 + dense residual [hf:Snowflake/snowflake-arctic-base].

Arctic's signature dense-MoE hybrid: every layer has a (small) dense FFN
residual branch in parallel with the 128-expert MoE. Expert d_ff = 4864 as
assigned; the dense branch uses 2*d_model (approximation, noted).
At 480B params the dry-run dtype policy is bf16 params + bf16 Adam moments
(fits 256 x 16 GB; see DESIGN.md Sec 6).
"""
from repro.configs.common import ArchSpec
from repro.models.moe import MoEConfig
from repro.models.transformer import ModelConfig

_FULL = ModelConfig(
    name="arctic-480b",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=14336,  # dense path (unused: every layer is MoE)
    vocab=32000,
    head_dim=128,
    act="swiglu",
    tie_embeddings=False,
    param_dtype="bfloat16",
    moe=MoEConfig(num_experts=128, top_k=2, d_ff=4864, dense_residual=True,
                  d_ff_dense=14336, every_n=1),
)

_REDUCED = ModelConfig(
    name="arctic-reduced",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab=128,
    act="swiglu",
    tie_embeddings=False,
    compute_dtype="float32",
    moe=MoEConfig(num_experts=4, top_k=2, d_ff=64, dense_residual=True,
                  d_ff_dense=128, every_n=1),
)


def spec() -> ArchSpec:
    return ArchSpec(model=_FULL, reduced=_REDUCED, opt_dtype="bfloat16",
                    notes="full attention: long_500k N/A")
