"""codeqwen1.5-7b [dense]: 32L d=4096 32H (kv=32) d_ff=13440 vocab=92416.

qwen1.5 arch: QKV bias, SwiGLU [hf:Qwen/CodeQwen1.5-7B]."""
from repro.configs.common import ArchSpec
from repro.models.transformer import ModelConfig

_FULL = ModelConfig(
    name="codeqwen1.5-7b",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=13440,
    vocab=92416,
    qkv_bias=True,
    rope_theta=1e6,
    act="swiglu",
    tie_embeddings=False,
)

_REDUCED = ModelConfig(
    name="codeqwen-reduced",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=160,
    vocab=128,
    qkv_bias=True,
    act="swiglu",
    tie_embeddings=False,
    compute_dtype="float32",
)


def spec() -> ArchSpec:
    return ArchSpec(model=_FULL, reduced=_REDUCED,
                    notes="full attention: long_500k N/A")
