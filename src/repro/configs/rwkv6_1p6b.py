"""rwkv6-1.6b [ssm]: 24L d=2048 (attention-free) d_ff=7168 vocab=65536.

Finch: data-dependent decay linear attention [arXiv:2404.05892; unverified].
Attention-free => O(1) decode state; long_500k is the showcase shape.
head_dim 64 => 32 wkv heads.
"""
from repro.configs.common import ArchSpec
from repro.models.transformer import ModelConfig

_FULL = ModelConfig(
    name="rwkv6-1.6b",
    num_layers=24,
    d_model=2048,
    num_heads=32,  # wkv heads, head_dim 64
    num_kv_heads=32,
    d_ff=7168,
    vocab=65536,
    norm="layernorm",
    tie_embeddings=False,
    block_pattern=("rwkv",),
)

_REDUCED = ModelConfig(
    name="rwkv6-reduced",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab=128,
    norm="layernorm",
    tie_embeddings=False,
    compute_dtype="float32",
    block_pattern=("rwkv",),
)


def spec() -> ArchSpec:
    return ArchSpec(model=_FULL, reduced=_REDUCED, long_context_ok=True,
                    notes="attention-free; decode state O(1) in context")
