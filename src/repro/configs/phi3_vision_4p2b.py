"""phi-3-vision-4.2b [vlm]: 32L d=3072 32H (GQA kv=32) d_ff=8192 vocab=32064.

phi3-mini backbone + CLIP frontend [hf:microsoft/Phi-3-vision-128k-instruct].
The CLIP image frontend is a STUB per assignment: input_specs() hands the
backbone precomputed patch embeddings. RoPE theta 10k (the 128k-context
LongRoPE scaling is out of scope; noted in DESIGN.md).
"""
from repro.configs.common import ArchSpec
from repro.models.transformer import ModelConfig

_FULL = ModelConfig(
    name="phi-3-vision-4.2b",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab=32064,
    act="swiglu",
    norm="rmsnorm",
    tie_embeddings=False,
)

_REDUCED = ModelConfig(
    name="phi-3-vision-reduced",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab=128,
    act="swiglu",
    norm="rmsnorm",
    tie_embeddings=False,
    compute_dtype="float32",
)


def spec() -> ArchSpec:
    return ArchSpec(model=_FULL, reduced=_REDUCED, modality="vlm",
                    notes="full attention: long_500k N/A")
