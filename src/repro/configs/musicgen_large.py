"""musicgen-large [audio]: 48L d=2048 32H (kv=32) d_ff=8192 vocab=2048.

Decoder-only over EnCodec tokens [arXiv:2306.05284]. The EnCodec frontend is
a STUB: input_specs() provides frame embeddings. MusicGen uses pre-LN
LayerNorm + GELU; we keep those and use RoPE in place of its learned
positional embeddings (adaptation noted in DESIGN.md).
"""
from repro.configs.common import ArchSpec
from repro.models.transformer import ModelConfig

_FULL = ModelConfig(
    name="musicgen-large",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab=2048,
    act="gelu",
    norm="layernorm",
    tie_embeddings=False,
)

_REDUCED = ModelConfig(
    name="musicgen-reduced",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab=64,
    act="gelu",
    norm="layernorm",
    tie_embeddings=False,
    compute_dtype="float32",
)


def spec() -> ArchSpec:
    return ArchSpec(model=_FULL, reduced=_REDUCED, modality="audio",
                    notes="full attention: long_500k N/A")
