"""Sharding rules: param path + shape -> PartitionSpec on the production
mesh.

Policy (baseline; §Perf iterates on it):
  * tensor parallelism over "model": prefer head/expert/ffn dims; fall back
    to any dim the axis divides (GSPMD inserts the reduction collectives
    for row-parallel layouts).
  * FSDP over "data": after TP assignment, shard the largest remaining
    divisible dim of every >=2D param (params + Adam moments). The "pod"
    axis stays pure DP (gradient all-reduce only crosses pods — the slow
    DCN boundary moves bytes once per step, not per layer).
  * batch dims of inputs/caches over ("pod","data"); long-context decode
    (batch=1) shards the KV time axis over "data" instead.

Layer-stacked params (under "blocks") carry a leading repeats dim that is
never sharded; preference dims shift by one.
"""
from __future__ import annotations

import re

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (path regex, preferred dims for "model") — dims are for the UNSTACKED param
_TP_PREFS: list[tuple[str, list[int]]] = [
    (r"embed.*(table|out)", [0]),  # (V, D): vocab-parallel
    (r"mixer.*w[q]", [1, 2, 0]),  # (D, H, hd)
    (r"mixer.*w[kv]$", [1, 2, 0]),  # (D, G, hd)
    (r"mixer.*wo", [0, 2, 1]),  # (H, hd, D)
    (r"(ffn|dense).*w[ig]$", [1, 0]),  # (D, F) col-parallel
    (r"(ffn|dense).*wo$", [0, 1]),  # (F, D) row-parallel
    (r"ffn.*router", []),  # replicate router
    (r"mixer.*(in_proj)", [1, 0]),  # mamba (D, 2di)
    (r"mixer.*(x_proj)", [0, 1]),  # (di, r+2n)
    (r"mixer.*(dt_proj)", [1, 0]),
    (r"mixer.*(out_proj)", [0, 1]),
    (r"mixer.*(A_log)", [0]),
    (r"mixer.*conv$", [1]),
    (r"mixer.*w[rg]$", [1, 0]),  # rwkv (D, D)
    (r"mixer.*(wa|wb)", [0, 1]),
]

# MoE experts: (E, D, F)/(E, F, D) — expert-parallel first, then ffn dim
_TP_PREFS.insert(0, (r"ffn.*w[ig]$__3d", [0, 2, 1]))
_TP_PREFS.insert(0, (r"ffn.*wo$__3d", [0, 1, 2]))


def _prefs_for(path: str, ndim: int) -> list[int]:
    for pat, dims in _TP_PREFS:
        if pat.endswith("__3d"):
            if ndim == 3 and re.search(pat[: -len("__3d")], path):
                return dims
            continue
        if re.search(pat, path):
            return dims
    return list(range(ndim))  # no named rule: any divisible dim


def param_spec(path: str, shape: tuple[int, ...], mesh: Mesh) -> P:
    """TP + FSDP spec for one param leaf. `path` is normalized from
    jax.tree_util.keystr form ("['blocks'][0]['ffn']['wi']") to dotted
    ("blocks.0.ffn.wi") so the rule regexes can anchor on leaf names."""
    path = ".".join(re.findall(r"\w+", path))
    model_n = mesh.shape["model"] if "model" in mesh.axis_names else 1
    data_n = mesh.shape["data"] if "data" in mesh.axis_names else 1
    stacked = path.startswith("blocks") or ".blocks." in f".{path}."
    off = 1 if stacked else 0
    ndim = len(shape)
    if ndim - off < 1:
        return P()
    spec: list = [None] * ndim

    # §Perf H5: attention projections shard on the *heads* dim or not at
    # all. Falling back to head_dim makes QK^T contract a sharded-vs-
    # unsharded (or doubly-sharded) dim => per-layer fp32 score all-reduces
    # (measured 2.2 TB/step/device on mixtral train_4k).
    if re.search(r"mixer.*(wq|wk|wv)$", path) and ndim - off == 3:
        if shape[off + 1] % model_n == 0 and shape[off + 1] >= model_n:
            spec[off + 1] = "model"
        if shape[off] % data_n == 0:
            spec[off] = "data"
        return P(*spec)
    if re.search(r"mixer.*wo$", path) and ndim - off == 3:
        if shape[off] % model_n == 0 and shape[off] >= model_n:
            spec[off] = "model"
        if shape[off + 2] % data_n == 0:
            spec[off + 2] = "data"
        return P(*spec)

    # MoE expert weights: expert-parallel when E divides the model axis,
    # otherwise ffn-dim tensor parallel + FSDP over data on the other dim.
    # (H4 — sharding F jointly over (model, data) — fixed the weight-grad
    # gathers but broke the forward: refuted, see EXPERIMENTS.md §Perf.)
    if re.search(r"ffn.*(wi|wg|wo)$", path) and ndim - off == 3:
        fdim = off + 2 if re.search(r"w[ig]$", path) else off + 1
        other = off + 1 if fdim == off + 2 else off + 2
        if shape[off] % model_n == 0 and shape[off] >= model_n:
            spec[off] = "model"
            rest = [d for d in (off + 1, off + 2) if shape[d] % data_n == 0]
            if rest:
                spec[max(rest, key=lambda i: shape[i])] = "data"
        elif shape[fdim] % model_n == 0:
            spec[fdim] = "model"
            if shape[other] % data_n == 0:
                spec[other] = "data"
        return P(*spec)

    body = list(range(off, ndim))
    prefs = [d + off for d in _prefs_for(path, ndim - off)]
    # tensor parallel over "model"
    tp_dim = None
    for d in prefs:
        if d < ndim and shape[d] % model_n == 0 and shape[d] >= model_n:
            spec[d] = "model"
            tp_dim = d
            break
    # FSDP over "data": largest remaining divisible dim
    if ndim - off >= 2 or tp_dim is None:
        cands = [d for d in body if d != tp_dim and shape[d] % data_n == 0 and shape[d] >= data_n]
        if cands:
            d = max(cands, key=lambda i: shape[i])
            spec[d] = "data"
    return P(*spec)


def param_shardings(params_shapes, mesh: Mesh):
    """Pytree of NamedSharding matching a pytree of ShapeDtypeStructs."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    out = []
    for path, leaf in flat:
        spec = param_spec(jax.tree_util.keystr(path), leaf.shape, mesh)
        out.append(NamedSharding(mesh, spec))
    return jax.tree.unflatten(treedef, out)


def batch_spec(shape: tuple[int, ...], mesh: Mesh) -> P:
    """Inputs (B, S[, D]): batch over (pod, data) when divisible; batch=1
    long-context shards the sequence dim over data instead."""
    dp = [a for a in ("pod", "data") if a in mesh.axis_names]
    dp_n = int(np.prod([mesh.shape[a] for a in dp]))
    if shape[0] % dp_n == 0 and shape[0] >= dp_n:
        # single axis as a bare name: PartitionSpec(("data",)) != P("data")
        # on this jax version
        return P(dp[0] if len(dp) == 1 else tuple(dp), *([None] * (len(shape) - 1)))
    if shape[0] % mesh.shape.get("data", 1) == 0 and shape[0] >= mesh.shape.get("data", 1):
        return P("data", *([None] * (len(shape) - 1)))
    if len(shape) > 1 and shape[1] % mesh.shape.get("data", 1) == 0:
        return P(None, "data", *([None] * (len(shape) - 2)))
    return P(*([None] * len(shape)))


def cache_spec(shape: tuple[int, ...], mesh: Mesh) -> P:
    """Decode caches, stacked (R, B, T, ...) or (R, B, ...): batch over
    (pod,data) if divisible else time over data; heads over model."""
    dp = [a for a in ("pod", "data") if a in mesh.axis_names]
    dp_n = int(np.prod([mesh.shape[a] for a in dp]))
    data_n = mesh.shape.get("data", 1)
    model_n = mesh.shape.get("model", 1)
    spec: list = [None] * len(shape)
    used_data = False
    if len(shape) >= 2 and shape[1] % dp_n == 0 and shape[1] >= dp_n:
        spec[1] = dp[0] if len(dp) == 1 else tuple(dp)
        used_data = True
    elif len(shape) >= 3 and shape[2] % data_n == 0 and shape[2] >= data_n:
        spec[2] = "data"  # shard KV time axis (long-context, batch=1)
        used_data = True
    # shard a heads/feature dim over model: prefer dims after time
    for d in range(len(shape) - 1, 1, -1):
        if spec[d] is None and shape[d] % model_n == 0 and shape[d] >= model_n:
            spec[d] = "model"
            break
    del used_data
    return P(*spec)


def cache_shardings(cache_shapes, mesh: Mesh):
    return jax.tree.map(
        lambda x: NamedSharding(mesh, cache_spec(x.shape, mesh)), cache_shapes
    )
