"""Production mesh builders.

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).

Single pod: 16x16 = 256 chips (v5e pod), axes (data, model).
Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model); the pod axis is
pure data parallelism across the DCN/ICI boundary.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """Axes used for data parallelism / FSDP."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def make_host_mesh(num_devices: int | None = None, axis: str = "data") -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests, examples)."""
    n = num_devices or len(jax.devices())
    return jax.make_mesh((n,), (axis,))
