"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST set XLA_FLAGS before any other import (jax locks the device count on
first init). Smoke tests and benches never import this module, so they see
the real single CPU device.

Per cell this proves, without hardware:
  * the sharding config is coherent (no mismatched collectives),
  * the program fits (memory_analysis),
  * and it yields the roofline terms (cost_analysis + collective bytes
    parsed from the compiled HLO) consumed by launch/roofline.py.

FLOPs accounting: XLA's cost model counts a `while` (lax.scan over layers)
body ONCE, so the scanned production program under-reports compute. Each
cell therefore also compiles two cheap *probes* with the layer scan fully
unrolled at R=1 and R=2 pattern units; per metric m,
    body = m(R=2) - m(R=1),   total = m(R=1) + (repeats - 1) * body.
The compile-proof, memory analysis, and HLO are always taken from the real
scanned program.

Usage:
  python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import logging  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402

from repro.configs import ARCHS, SHAPES, get_arch  # noqa: E402
from repro.launch import sharding as shard  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.transformer import (  # noqa: E402
    apply_model,
    decode_step,
    init_cache,
    init_params,
)
from repro.train import AdamWConfig, TrainConfig, make_train_step  # noqa: E402
from repro.train.optimizer import init_state  # noqa: E402

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)(-start)?\b"
)
_TYPE_RE = re.compile(
    r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([0-9,]*)\]"
)


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result bytes of every collective op in the (SPMD, per-device)
    module. `-done` ops are skipped (their `-start` was counted)."""
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "=" not in line:
            continue
        if f"{m.group(1)}-done" in line:
            continue
        lhs = line.split("=", 1)[0] + "=" + line.split("=", 1)[1].split(m.group(1))[0]
        nbytes = 0
        for dt, dims in _TYPE_RE.findall(lhs):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        if nbytes:
            out[m.group(1)] = out.get(m.group(1), 0) + nbytes
    return out


def collective_bytes_scaled(hlo_text: str, repeats: int) -> dict[str, int]:
    """Like collective_bytes, but collectives inside `while` bodies are
    multiplied by `repeats` (the layer-scan trip count). More robust than
    the R1/R2 probe correction when GSPMD picks different strategies at
    different unroll factors (observed on MoE cells). Approximation: every
    while body is assumed to be a layer scan; inner scans (mamba chunks,
    rwkv time) would be over-scaled — none of the §Perf cells contain them.
    """
    # find computations used as while bodies
    bodies = set(re.findall(r"body=%?([\w.\-]+)", hlo_text))
    out: dict[str, int] = {}
    current: str | None = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and "(" in stripped and "=" not in stripped.split("(")[0]:
            name = stripped.split("(")[0].strip().lstrip("%")
            name = name.replace("ENTRY", "").strip().lstrip("%")
            if name:
                current = name
            continue
        if stripped == "}":
            current = None
            continue
        m = _COLL_RE.search(line)
        if not m or "=" not in line or f"{m.group(1)}-done" in line:
            continue
        lhs = line.split("=", 1)[0] + "=" + line.split("=", 1)[1].split(m.group(1))[0]
        nbytes = 0
        for dt, dims in _TYPE_RE.findall(lhs):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        if nbytes:
            mult = repeats if current in bodies else 1
            out[m.group(1)] = out.get(m.group(1), 0) + nbytes * mult
    return out


def _sds(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _cost(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def _memory(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:
        # a backend with no memory analysis is a real signal (the fit proof
        # never happened) — log it and carry it into the dry-run record
        # instead of silently reporting an empty footprint
        logging.getLogger(__name__).warning(
            "memory_analysis failed: %s: %s", type(e).__name__, e
        )
        return {"error": f"{type(e).__name__}: {e}"}
    if ma is None:
        return {}
    keys = [
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
    ]
    return {k: getattr(ma, k, None) for k in keys}


def _metrics(compiled) -> dict:
    cost = _cost(compiled)
    colls = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0) or 0.0),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0) or 0.0),
        "collective_bytes": colls,
    }


def _set_constraints(cfg, mesh, seq: int, batch: int, kind: str):
    """Pin the activation shardings GSPMD won't find on its own: the
    residual stream (batch over dp axes) and the logits (vocab over model)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.models import layers as L

    L.clear_constraints()
    s = 1 if kind == "decode" else seq
    act = shard.batch_spec((batch, s, cfg.d_model), mesh)
    bdim = act[0] if len(act) else None
    L.set_constraint("act" if kind != "decode" else "act_dec",
                     NamedSharding(mesh, act))
    if cfg.vocab % mesh.shape.get("model", 1) == 0:
        L.set_constraint("logits", NamedSharding(mesh, P(bdim, None, "model")))
    if cfg.moe is not None:
        # §Perf H1 (confirmed): batch-sharded MoE dispatch/combine buffers
        # kill the resharding collective-permutes GSPMD otherwise inserts.
        L.set_constraint("moe_buf", NamedSharding(mesh, P(bdim, None, None, None)))
        L.set_constraint("moe_y", NamedSharding(mesh, P(bdim, None, None)))


def _lower_kind(spec, cfg, shape_name: str, mesh, opt_dtype: str, microbatches: int = 1):
    """Lower + compile one program for (cfg, shape) on mesh."""
    seq, batch, kind = SHAPES[shape_name]
    params_shapes = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
    pshard = shard.param_shardings(params_shapes, mesh)
    ins = spec.input_specs(shape_name)
    _set_constraints(cfg, mesh, seq, batch, kind)
    with mesh:
        if kind == "train":
            tcfg = TrainConfig(
                adamw=AdamWConfig(moment_dtype=opt_dtype), microbatches=microbatches
            )
            opt_shapes = jax.eval_shape(lambda p: init_state(tcfg.adamw, p), params_shapes)
            oshard = {
                "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
                "m": pshard,
                "v": pshard,
            }
            bshard = jax.tree.map(
                lambda x: jax.sharding.NamedSharding(mesh, shard.batch_spec(x.shape, mesh)),
                ins,
            )
            step_fn = make_train_step(cfg, tcfg)
            jitted = jax.jit(
                step_fn,
                in_shardings=(pshard, oshard, bshard),
                out_shardings=(pshard, oshard, None),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(_sds(params_shapes), _sds(opt_shapes), ins)
        elif kind == "prefill":
            bshard = jax.tree.map(
                lambda x: jax.sharding.NamedSharding(mesh, shard.batch_spec(x.shape, mesh)),
                ins,
            )
            jitted = jax.jit(
                lambda p, inputs: apply_model(p, cfg, inputs),
                in_shardings=(pshard, bshard["inputs"]),
            )
            lowered = jitted.lower(_sds(params_shapes), ins["inputs"])
        else:  # decode
            cache_len = min(seq, cfg.sliding_window) if cfg.sliding_window else seq
            cache_shapes = jax.eval_shape(lambda: init_cache(cfg, batch, cache_len))
            cshard = shard.cache_shardings(cache_shapes, mesh)
            tok = ins["inputs"]
            tshard = jax.sharding.NamedSharding(mesh, shard.batch_spec(tok.shape, mesh))
            scalar = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
            jitted = jax.jit(
                lambda p, t, c, n: decode_step(p, cfg, t, c, n),
                in_shardings=(pshard, tshard, cshard, scalar),
                out_shardings=(None, cshard),
                donate_argnums=(2,),
            )
            lowered = jitted.lower(
                _sds(params_shapes), tok, _sds(cache_shapes), ins["cur_len"]
            )
        compiled = lowered.compile()
    return compiled, params_shapes


def lower_cell(arch: str, shape_name: str, multi_pod: bool, probes: bool = True):
    """Lower + compile one (arch, shape, mesh) cell; returns the record."""
    spec = get_arch(arch)
    if not spec.shape_supported(shape_name):
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "why": spec.notes}
    cfg = spec.model
    mesh = make_production_mesh(multi_pod=multi_pod)
    unit = len(cfg.block_pattern)
    repeats = cfg.repeats
    t0 = time.time()
    compiled, params_shapes = _lower_kind(spec, cfg, shape_name, mesh, spec.opt_dtype)
    raw = _metrics(compiled)
    mem = _memory(compiled)
    t_main = time.time() - t0
    mem_mb8 = None
    if SHAPES[shape_name][2] == "train":
        # production memory config: 8-way gradient accumulation (activation
        # temps scale ~1/8; flops accounting stays on the mb=1 program)
        c8, _ = _lower_kind(spec, cfg, shape_name, mesh, spec.opt_dtype, microbatches=8)
        mem_mb8 = _memory(c8)

    rec = {
        "arch": arch,
        "shape": shape_name,
        "multi_pod": multi_pod,
        "status": "ok",
        "mesh": dict(mesh.shape),
        "seconds_to_compile": round(t_main, 1),
        "raw": raw,
        "memory": mem,
        "memory_mb8": mem_mb8,
        "params_total": int(sum(x.size for x in jax.tree.leaves(params_shapes))),
        "repeats": repeats,
        "unit_layers": unit,
    }
    if probes:
        t1 = time.time()
        spec1 = dataclasses.replace(
            spec, model=dataclasses.replace(cfg, num_layers=unit, scan_unroll=True)
        )
        spec2 = dataclasses.replace(
            spec, model=dataclasses.replace(cfg, num_layers=2 * unit, scan_unroll=True)
        )
        c1, _ = _lower_kind(spec1, spec1.model, shape_name, mesh, spec.opt_dtype)
        m1 = _metrics(c1)
        c2, _ = _lower_kind(spec2, spec2.model, shape_name, mesh, spec.opt_dtype)
        m2 = _metrics(c2)

        def corrected(key):
            if key == "collective_bytes":
                ops = set(m1[key]) | set(m2[key]) | set(raw[key])
                out = {}
                for op in ops:
                    body = max(0.0, m2[key].get(op, 0) - m1[key].get(op, 0))
                    out[op] = m1[key].get(op, 0) + (repeats - 1) * body
                return out
            body = max(0.0, m2[key] - m1[key])
            return m1[key] + (repeats - 1) * body

        rec["flops"] = corrected("flops")
        rec["bytes_accessed"] = corrected("bytes_accessed")
        rec["collective_bytes"] = corrected("collective_bytes")
        rec["probe_seconds"] = round(time.time() - t1, 1)
        rec["probe_r1"] = m1
        rec["probe_r2"] = m2
    else:
        rec["flops"] = raw["flops"]
        rec["bytes_accessed"] = raw["bytes_accessed"]
        rec["collective_bytes"] = raw["collective_bytes"]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCHS:
            for shape in SHAPES:
                cells.append((arch, shape, args.multi_pod))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape, args.multi_pod))

    os.makedirs(args.out, exist_ok=True)
    for arch, shape, mp in cells:
        tag = f"{arch}_{shape}_{'pod2' if mp else 'pod1'}"
        path = os.path.join(args.out, tag + ".json")
        if args.skip_existing and os.path.exists(path):
            print(f"[cached  ] {tag}", flush=True)
            continue
        try:
            rec = lower_cell(arch, shape, mp, probes=not args.no_probes)
        except Exception as e:  # a dry-run failure is a bug: record loudly
            rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                   "status": "FAILED", "error": f"{type(e).__name__}: {e}"}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        status = rec["status"]
        extra = (
            f"flops/dev={rec.get('flops'):.3e}"
            f" coll={sum(rec.get('collective_bytes', {}).values()):.3e}B"
            f" compile={rec.get('seconds_to_compile')}s"
            if status == "ok" and rec.get("flops")
            else rec.get("why", rec.get("error", ""))
        )
        print(f"[{status:8s}] {tag:55s} {extra}", flush=True)


if __name__ == "__main__":
    main()
