"""End-to-end training driver.

CPU-scale by default (reduced configs); the same code path lowers on the
production mesh in dryrun.py. Fault tolerance: resumes from the latest
checkpoint; the data stream is a pure function of step, so resume is exact.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
      --steps 200 --reduced --batch 8 --seq 64
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_arch
from repro.train import AdamWConfig, TrainConfig, checkpoint, make_train_step
from repro.train.data import DataConfig, markov_batch
from repro.train.straggler import StragglerMonitor
from repro.train.trainer import init_train_state


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=20)
    args = ap.parse_args(argv)

    spec = get_arch(args.arch)
    cfg = spec.reduced if args.reduced else spec.model
    tcfg = TrainConfig(
        adamw=AdamWConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps),
        microbatches=args.microbatches,
    )
    params, opt_state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    start = 0
    if args.ckpt_dir:
        latest = checkpoint.latest_step(args.ckpt_dir)
        if latest is not None:
            state = checkpoint.restore(
                args.ckpt_dir, latest, {"params": params, "opt": opt_state}
            )
            params, opt_state = state["params"], state["opt"]
            start = latest
            print(f"resumed from step {start}")

    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch)
    mon = StragglerMonitor(num_hosts=1)
    t_hist = []
    for step in range(start, args.steps):
        batch = jax.tree.map(jnp.asarray, markov_batch(dcfg, step))
        if spec.modality != "text":  # stub frontend: embed ids as floats
            emb = jax.nn.one_hot(batch["inputs"] % cfg.d_model, cfg.d_model, dtype=jnp.float32)
            batch = {"inputs": emb, "labels": batch["labels"]}
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        t_hist.append(time.time() - t0)
        if (step + 1) % args.log_every == 0:
            print(
                f"step {step + 1:5d} loss {float(metrics['loss']):.4f} "
                f"lr {float(metrics['lr']):.2e} gnorm {float(metrics['grad_norm']):.2f} "
                f"{t_hist[-1] * 1e3:.0f} ms"
            )
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            checkpoint.save(args.ckpt_dir, step + 1, {"params": params, "opt": opt_state})
        if len(t_hist) >= 20:
            import numpy as np

            mon.observe(np.array([sum(t_hist) / len(t_hist)]))
            t_hist = []
    print("done")
    return params


if __name__ == "__main__":
    main()
