"""§Perf hillclimbing driver: one compile per (cell, levers) with stable
metrics — raw cost_analysis flops + while-body-scaled collective bytes (see
dryrun.collective_bytes_scaled; robust where the R1/R2 probe correction is
not). Appends every measurement to benchmarks/results/perf_log.jsonl so the
hypothesis -> change -> measure log in EXPERIMENTS.md is reproducible.

  python -m repro.launch.perf --arch mixtral-8x22b --shape train_4k \
      --tag baseline [--moe-buf dp,,model,] [--remat dots] [--last-only] ...
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import SHAPES, get_arch  # noqa: E402
from repro.launch import sharding as shard  # noqa: E402
from repro.launch.dryrun import (  # noqa: E402
    _cost,
    _memory,
    _sds,
    _set_constraints,
    collective_bytes_scaled,
)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import layers as L  # noqa: E402
from repro.models.transformer import apply_model, init_params  # noqa: E402
from repro.train import AdamWConfig, TrainConfig, make_train_step  # noqa: E402
from repro.train.optimizer import init_state  # noqa: E402

PEAK, HBM, LINK = 197e12, 819e9, 50e9


def _parse_spec(s: str) -> P:
    """'dp,,model,' -> P(('pod','data'?), None, 'model', None); 'dp' means
    the data axes tuple, '' means None."""
    parts = []
    for tok in s.split(","):
        if tok == "":
            parts.append(None)
        elif tok == "dp":
            parts.append(("data",))
        else:
            parts.append(tok)
    return P(*parts)


def measure(arch: str, shape: str, levers: dict) -> dict:
    spec = get_arch(arch)
    cfg = spec.model
    if levers.get("remat"):
        cfg = dataclasses.replace(cfg, remat_policy=levers["remat"])
    if levers.get("q_chunk") is not None:
        cfg = dataclasses.replace(cfg, attn_q_chunk=levers["q_chunk"])
    mesh = make_production_mesh(multi_pod=False)
    seq, batch, kind = SHAPES[shape]
    params_shapes = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
    pshard = shard.param_shardings(params_shapes, mesh)
    ins = spec.input_specs(shape)
    _set_constraints(cfg, mesh, seq, batch, kind)
    for name in ("moe_buf", "moe_y", "moe_out"):
        if levers.get(name):
            L.set_constraint(name, NamedSharding(mesh, _parse_spec(levers[name])))
    t0 = time.time()
    with mesh:
        if kind == "train":
            tcfg = TrainConfig(
                adamw=AdamWConfig(moment_dtype=spec.opt_dtype),
                microbatches=levers.get("microbatches", 1),
            )
            opt_shapes = jax.eval_shape(lambda p: init_state(tcfg.adamw, p), params_shapes)
            oshard = {"step": NamedSharding(mesh, P()), "m": pshard, "v": pshard}
            bshard = jax.tree.map(
                lambda x: NamedSharding(mesh, shard.batch_spec(x.shape, mesh)), ins
            )
            fn = jax.jit(
                make_train_step(cfg, tcfg),
                in_shardings=(pshard, oshard, bshard),
                out_shardings=(pshard, oshard, None),
                donate_argnums=(0, 1),
            )
            compiled = fn.lower(_sds(params_shapes), _sds(opt_shapes), ins).compile()
        elif kind == "prefill":
            bs = NamedSharding(mesh, shard.batch_spec(ins["inputs"].shape, mesh))
            last_only = bool(levers.get("last_only"))
            fn = jax.jit(
                lambda p, x: apply_model(p, cfg, x, last_only=last_only),
                in_shardings=(pshard, bs),
            )
            compiled = fn.lower(_sds(params_shapes), ins["inputs"]).compile()
        else:
            raise NotImplementedError("decode cells not used in §Perf")
    cost = _cost(compiled)
    colls = collective_bytes_scaled(compiled.as_text(), cfg.repeats)
    mem = _memory(compiled)
    flops = float(cost.get("flops", 0.0))
    rec = {
        "arch": arch,
        "shape": shape,
        "levers": levers,
        "compile_s": round(time.time() - t0, 1),
        "flops_raw": flops,
        "collective_bytes_scaled": colls,
        "collective_total": sum(colls.values()),
        "collective_s": sum(colls.values()) / LINK,
        "memory": mem,
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--moe-buf", default=None)
    ap.add_argument("--moe-y", default=None)
    ap.add_argument("--moe-out", default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--q-chunk", type=int, default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--last-only", action="store_true")
    args = ap.parse_args()
    levers = {
        k: v
        for k, v in {
            "moe_buf": args.moe_buf,
            "moe_y": args.moe_y,
            "moe_out": args.moe_out,
            "remat": args.remat,
            "q_chunk": args.q_chunk,
            "microbatches": args.microbatches,
            "last_only": args.last_only,
        }.items()
        if not (v is None or v is False or (k == "microbatches" and v == 1))
    }
    rec = measure(args.arch, args.shape, levers)
    rec["tag"] = args.tag
    os.makedirs("benchmarks/results", exist_ok=True)
    with open("benchmarks/results/perf_log.jsonl", "a") as f:
        f.write(json.dumps(rec) + "\n")
    print(
        f"[{args.tag}] {args.arch}/{args.shape} flops_raw={rec['flops_raw']:.3e} "
        f"coll={rec['collective_total']:.3e}B ({rec['collective_s']:.2f}s) "
        f"temp={rec['memory'].get('temp_size_in_bytes', 0) / 1e9:.1f}GB "
        f"compile={rec['compile_s']}s"
    )
    for op, b in sorted(rec["collective_bytes_scaled"].items(), key=lambda kv: -kv[1]):
        print(f"    {op:20s} {b:.3e} B")


if __name__ == "__main__":
    main()
