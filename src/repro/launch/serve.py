"""Serving driver: continuous batching over a reduced config on CPU.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_arch
from repro.models.transformer import init_params
from repro.serve import DecodeServeEngine, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="gemma-2b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args(argv)

    spec = get_arch(args.arch)
    cfg = spec.reduced
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = DecodeServeEngine(params, cfg, slots=args.slots, max_len=args.max_len)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, int(rng.integers(2, 12))).astype(np.int32)
        eng.submit(Request(rid=i, prompt=prompt, max_new=args.max_new))
    t0 = time.time()
    eng.run()
    dt = time.time() - t0
    toks = args.requests * args.max_new
    print(
        f"served {args.requests} requests ({toks} tokens) in {eng.steps} engine steps,"
        f" {dt:.2f}s ({toks / dt:.1f} tok/s on CPU, reduced config)"
    )


if __name__ == "__main__":
    main()
