"""Roofline analysis over the dry-run records (launch/dryrun.py output).

Per (arch, shape, single-pod mesh):
    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw
plus MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D (prefill/decode)
and the useful-compute ratio MODEL_FLOPS / (HLO_FLOPs * chips).

Hardware constants (TPU v5e-class, per chip): 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI.

Caveats recorded with the table:
  * HLO bytes come from XLA's per-op cost model, which does not see through
    fusion on the CPU backend — it over-counts HBM traffic; the memory term
    is an upper bound.
  * collective bytes are summed result sizes of collective ops in the SPMD
    module (all-reduce counted once, not 2(P-1)/P ring passes).

Usage: python -m repro.launch.roofline [--dir benchmarks/results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
LINK_BW = 50e9  # B/s / link

# shapes: (seq, global_batch, kind)
from repro.configs import SHAPES, get_arch  # noqa: E402


def model_flops(arch: str, shape: str) -> float:
    """Analytic MODEL_FLOPS: 6*N_active*D for training, 2*N_active*D for
    forward-only (per decoded token for decode shapes)."""
    spec = get_arch(arch)
    cfg = spec.model
    seq, batch, kind = SHAPES[shape]
    import jax

    shapes = jax.eval_shape(
        lambda k: __import__(
            "repro.models.transformer", fromlist=["init_params"]
        ).init_params(k, cfg),
        jax.random.PRNGKey(0),
    )
    total = sum(x.size for x in jax.tree.leaves(shapes))
    if cfg.moe is not None:
        # subtract inactive expert params
        m = cfg.moe
        moe_layers = sum(
            1 for i in range(cfg.num_layers) if cfg.is_moe_layer(i % len(cfg.block_pattern))
        )
        expert_params = moe_layers * m.num_experts * (
            (2 * cfg.d_model * m.d_ff) + (m.d_ff * cfg.d_model)
        )
        active = total - expert_params + expert_params * (m.top_k / m.num_experts)
    else:
        active = total
    tokens = batch * seq if kind != "decode" else batch  # decode: 1 token/seq
    mult = 6.0 if kind == "train" else 2.0
    return mult * active * tokens


def load(dir_: str, multi_pod: bool = False):
    recs = []
    for f in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        r = json.load(open(f))
        if r.get("multi_pod") != multi_pod:
            continue
        recs.append(r)
    return recs


def analyse(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    chips = 1
    for v in rec["mesh"].values():
        chips *= v
    flops = rec["flops"]
    comp = flops / PEAK_FLOPS
    memb = rec["bytes_accessed"] / HBM_BW
    collb = sum(rec["collective_bytes"].values()) / LINK_BW
    mf = model_flops(rec["arch"], rec["shape"])
    useful = mf / (flops * chips) if flops else 0.0
    dom = max((comp, "compute"), (memb, "memory"), (collb, "collective"))[1]
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "chips": chips,
        "compute_s": comp,
        "memory_s": memb,
        "collective_s": collb,
        "dominant": dom,
        "model_flops": mf,
        "useful_ratio": useful,
        "roofline_fraction": useful,  # of the compute roof, per chip
        "temp_gb": (rec.get("memory_mb8") or rec.get("memory", {})).get("temp_size_in_bytes", 0)
        / 1e9
        if rec.get("memory")
        else None,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="benchmarks/results/dryrun")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--csv", default=None)
    args = ap.parse_args()
    rows = []
    for rec in load(args.dir, args.multi_pod):
        if rec.get("status") == "skipped":
            rows.append({"arch": rec["arch"], "shape": rec["shape"], "dominant": "N/A",
                         "why": rec.get("why", "")})
            continue
        a = analyse(rec)
        if a:
            rows.append(a)
        else:
            rows.append({"arch": rec["arch"], "shape": rec["shape"], "dominant": "FAILED",
                         "why": rec.get("error", "")})
    hdr = (
        f"{'arch':24s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s}"
        f" {'coll_s':>10s} {'dominant':>10s} {'useful':>7s}"
    )
    print(hdr)
    for r in rows:
        if "compute_s" in r:
            print(
                f"{r['arch']:24s} {r['shape']:12s} {r['compute_s']:10.4f} {r['memory_s']:10.4f}"
                f" {r['collective_s']:10.4f} {r['dominant']:>10s} {r['useful_ratio']:7.1%}"
            )
        else:
            print(
                f"{r['arch']:24s} {r['shape']:12s} {'-':>10s} {'-':>10s} {'-':>10s}"
                f" {r['dominant']:>10s}  {r.get('why', '')[:40]}"
            )
    if args.csv:
        import csv

        with open(args.csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=sorted({k for r in rows for k in r}))
            w.writeheader()
            w.writerows(rows)


if __name__ == "__main__":
    main()
