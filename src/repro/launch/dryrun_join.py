"""Dry-run the distributed Free Join engine itself on the production mesh.

Lowers + compiles the shard_map'd HyperCube count (local compiled Free Join
+ psum) for the triangle and clover queries on both production meshes,
sharding over the flattened device grid. Proves the paper-pillar program is
coherent at 512 chips, and records its roofline terms next to the LM cells.

  python -m repro.launch.dryrun_join [--multi-pod]
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core import binary2fj, factor  # noqa: E402
from repro.core.compiled import make_count_fn  # noqa: E402
from repro.launch.dryrun import _cost, _memory, collective_bytes  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.relational.schema import clover_query, triangle_query  # noqa: E402

try:  # top-level alias only exists on newer jax
    shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map  # noqa: E402


def lower_join(multi_pod: bool, rows_per_shard: int = 65536, cap: int = 1 << 20):
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = tuple(mesh.axis_names)  # flatten the whole grid into shards
    nshards = 1
    for a in axes:
        nshards *= mesh.shape[a]
    out = []
    for q in (triangle_query(), clover_query()):
        fj = factor(binary2fj(q.atoms, q))
        local = make_count_fn(fj, [cap] * 4, impl="jnp")

        def per_shard(cols):
            cols = jax.tree.map(lambda x: x[0], cols)
            c, ovf = local(cols)
            # count + overflow flag psum'd separately: no sentinel can ever
            # reach the caller (mirrors distributed.spmd_count's contract)
            return jax.lax.psum(c, axes), jax.lax.psum(ovf.astype(jnp.int32), axes)

        cols_sds = {
            a.alias: {
                v: jax.ShapeDtypeStruct((nshards, rows_per_shard), jnp.int32)
                for v in a.vars
            }
            for a in q.atoms
        }
        spec = P(axes)
        with mesh:
            fn = jax.jit(
                shard_map(
                    per_shard,
                    mesh=mesh,
                    in_specs=(jax.tree.map(lambda _: spec, cols_sds),),
                    out_specs=(P(), P()),
                    # the probe's early-exit while_loop has no replication
                    # rule; outputs are explicitly psum-reduced
                    check_rep=False,
                )
            )
            t0 = time.time()
            compiled = fn.lower(cols_sds).compile()
            dt = time.time() - t0
        cost = _cost(compiled)
        rec = {
            "query": str(q),
            "multi_pod": multi_pod,
            "shards": nshards,
            "rows_per_shard": rows_per_shard,
            "compile_s": round(dt, 1),
            "flops_per_device": cost.get("flops"),
            "bytes_per_device": cost.get("bytes accessed"),
            "collective_bytes": collective_bytes(compiled.as_text()),
            "memory": _memory(compiled),
        }
        out.append(rec)
        print(
            f"[ok] join dry-run {q} shards={nshards} flops/dev={rec['flops_per_device']:.3e} "
            f"coll={sum(rec['collective_bytes'].values()):.3e}B compile={dt:.1f}s"
        )
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="benchmarks/results/dryrun_join.json")
    args = ap.parse_args()
    recs = lower_join(args.multi_pod)
    existing = []
    if os.path.exists(args.out):
        existing = json.load(open(args.out))
    with open(args.out, "w") as f:
        json.dump(existing + recs, f, indent=1)


if __name__ == "__main__":
    main()
