"""Fig. 16 + Fig. 19 reproduction: LSQB-like q1-q5 across scaling factors.

COUNT(*) queries (LSQB's output >> input, so counting is the benchmark's
own aggregation; Free Join additionally benefits from factorized counting —
the Fig. 19 effect — which we also isolate on q1 by disabling it)."""
from __future__ import annotations

from benchmarks.common import timeit
from benchmarks.datagen import lsqb_queries, lsqb_tables
from repro.core import binary_join, free_join, generic_join, optimize


def run(sfs=(0.03, 0.1, 0.3), repeats: int = 2):
    rows = []
    for sf in sfs:
        tables = lsqb_tables(sf)
        for name, q, rels in lsqb_queries(tables):
            tree = optimize(q, rels)
            t_fj, c_fj = timeit(lambda: free_join(q, rels, tree, agg="count"), repeats, warmup=0)
            t_bj, c_bj = timeit(lambda: binary_join(q, rels, tree, agg="count"), repeats, warmup=0)
            t_gj, c_gj = timeit(
                lambda: generic_join(q, rels, plan_tree=tree, agg="count"), repeats, warmup=0
            )
            assert c_fj == c_bj == c_gj, (name, sf, c_fj, c_bj, c_gj)
            rows.append(
                {
                    "name": f"lsqb.{name}.sf{sf}.free_join",
                    "us": t_fj * 1e6,
                    "derived": f"count={c_fj};bj/fj={t_bj / t_fj:.2f}x;gj/fj={t_gj / t_fj:.2f}x",
                }
            )
            rows.append(
                {"name": f"lsqb.{name}.sf{sf}.binary_join", "us": t_bj * 1e6, "derived": ""}
            )
            rows.append(
                {"name": f"lsqb.{name}.sf{sf}.generic_join", "us": t_gj * 1e6, "derived": ""}
            )
    # Fig. 19: factorized output. LSQB q1's output >> input; the paper made
    # it "significantly faster" by keeping the output factorized. Our
    # permuted-skew q1 has a tiny count, so we isolate the same effect on
    # the high-output 2-hop query (output ~ sum of degree products).
    from repro.relational.schema import Atom, Query

    import numpy as np

    from repro.relational.relation import Relation

    rng = np.random.default_rng(7)
    n_nodes = 20_000
    # 20 hubs with in/out degree 500 => output ~ 20*500^2 = 5M >> 60k input
    hubs = np.arange(20)
    hub_in = np.stack([rng.integers(0, n_nodes, 10_000), np.repeat(hubs, 500)])
    hub_out = np.stack([np.repeat(hubs, 500), rng.integers(0, n_nodes, 10_000)])
    bg = np.stack([rng.integers(0, n_nodes, 40_000), rng.integers(0, n_nodes, 40_000)])
    src, dst = np.concatenate([hub_in, hub_out, bg], axis=1).astype(np.int64)
    knows = Relation("knows", {"a": src, "b": dst})
    q = Query([Atom("knows", ("a", "b"), "K1"), Atom("knows", ("b", "c"), "K2")])
    rels = {"K1": knows, "K2": knows.rename({"a": "b", "b": "c"})}
    tree = optimize(q, rels)
    t_fact, c1 = timeit(lambda: free_join(q, rels, tree, agg="count"), repeats, warmup=0)

    def materialized_count():
        bound, mult = free_join(q, rels, tree)
        return int(mult.sum())

    t_mat, c2 = timeit(materialized_count, repeats, warmup=0)
    assert c1 == c2, (c1, c2)
    rows.append(
        {
            "name": "lsqb.2hop.fig19_factorized_output",
            "us": t_fact * 1e6,
            "derived": f"count={c1};materialized_us={t_mat * 1e6:.0f}"
            f";speedup={t_mat / t_fact:.2f}x",
        }
    )
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
