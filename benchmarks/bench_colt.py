"""Fig. 17 reproduction: trie data-structure ablation.

Free Join executed with
  simple trie (all levels built eagerly — classic Generic Join trie),
  SLT (level 0 eager, inner levels lazy, unfiltered — Freitag et al. [7]),
  COLT (all levels on demand + alive-filtered — this paper).
Same plans, same engine; only the build laziness differs. Paper: COLT
1.91x / 8.47x geomean over SLT / simple."""
from __future__ import annotations

import numpy as np

from benchmarks.common import timeit
from benchmarks.datagen import job_queries, job_tables, lsqb_queries, lsqb_tables
from repro.core import free_join, optimize
from repro.core.engine import ExecStats


def run(scale: float = 0.1, repeats: int = 2):
    rows = []
    speed_slt, speed_simple = [], []
    queries = job_queries(job_tables(scale)) + lsqb_queries(lsqb_tables(scale / 2))
    for name, q, rels in queries:
        tree = optimize(q, rels)
        times = {}
        for mode in ("colt", "slt", "simple"):
            st = ExecStats()
            t, c = timeit(
                lambda m=mode, s=st: free_join(q, rels, tree, agg="count", mode=m, stats=s),
                repeats,
                warmup=0,
            )
            # build_ns accumulates across calls now; report the per-call mean
            times[mode] = (t, c, st.build_ns / 1e6 / max(1, repeats))
        c0 = times["colt"][1]
        assert all(v[1] == c0 for v in times.values()), name
        speed_slt.append(times["slt"][0] / times["colt"][0])
        speed_simple.append(times["simple"][0] / times["colt"][0])
        rows.append(
            {
                "name": f"colt.{name}",
                "us": times["colt"][0] * 1e6,
                "derived": f"slt/colt={speed_slt[-1]:.2f}x;simple/colt={speed_simple[-1]:.2f}x"
                f";build_ms(colt/slt/simple)={times['colt'][2]:.1f}"
                f"/{times['slt'][2]:.1f}/{times['simple'][2]:.1f}",
            }
        )
    gm = lambda v: float(np.exp(np.mean(np.log(v))))  # noqa: E731
    rows.append(
        {
            "name": "colt.geomean",
            "us": 0.0,
            "derived": f"slt/colt={gm(speed_slt):.2f}x;simple/colt={gm(speed_simple):.2f}x"
            f";max_slt={max(speed_slt):.2f}x;max_simple={max(speed_simple):.2f}x",
        }
    )
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
