"""Fig. 18 reproduction: vectorization batch-size ablation.

Uses the tuple-at-a-time engine (Fig. 7/13 literal execution) with batch
sizes 1 / 10 / 100 / 1000, plus the full-batch vectorized engine as the
limit. Paper: any vectorization beats none; batch 1000 is ~2.12x geomean
over batch 1. Small inputs — the per-tuple engine is a Python loop."""
from __future__ import annotations

import numpy as np

from benchmarks.common import timeit
from benchmarks.datagen import job_queries, job_tables
from repro.core import binary2fj, factor, free_join, optimize
from repro.core.tuple_engine import execute_tuples


def run(scale: float = 0.01, repeats: int = 1):
    rows = []
    tables = job_tables(scale)
    speedups = {10: [], 100: [], 1000: []}
    for name, q, rels in job_queries(tables):
        if name in ("q_clover_adv",):  # per-tuple binary-ish exploration is pathological here
            continue
        tree = optimize(q, rels)
        atoms = []
        for _, leaves in tree.decompose():
            atoms.extend(a for a in leaves if not isinstance(a, str))
        if len(atoms) != len(q.atoms):
            continue  # bushy: tuple engine runs single-stage plans only
        fj = factor(binary2fj(atoms, q))
        base = None
        for bs in (1, 10, 100, 1000):
            t, out = timeit(lambda b=bs: execute_tuples(fj, rels, batch_size=b), repeats, warmup=0)
            n = len(out)
            if bs == 1:
                base = t
            else:
                speedups[bs].append(base / t)
            rows.append(
                {
                    "name": f"vec.{name}.batch{bs}",
                    "us": t * 1e6,
                    "derived": f"|out|={n};vs_batch1={base / t:.2f}x" if bs > 1 else f"|out|={n}",
                }
            )
        t, c = timeit(lambda: free_join(q, rels, tree, agg="count"), repeats, warmup=0)
        rows.append({"name": f"vec.{name}.fullbatch", "us": t * 1e6, "derived": f"count={c}"})
    gm = lambda v: float(np.exp(np.mean(np.log(v)))) if v else 0.0  # noqa: E731
    rows.append(
        {
            "name": "vec.geomean_vs_batch1",
            "us": 0.0,
            "derived": ";".join(f"batch{b}={gm(v):.2f}x" for b, v in speedups.items()),
        }
    )
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
