"""Multi-tenant join serving: batched template dispatch vs serial calls.

The workload is a synthetic serving trace: T tenants issue point-lookup
triangle counts over one shared edge set, each tenant spelling the query
with its own aliases and carrying its own selection constant (``x = c``).
After canonicalization (serve.templates) every request collapses onto ONE
plan template, so the whole trace is the serving engine's best case and
the serial path's representative case — both pay exactly one compile.

Two ways to drain the trace:

  serial    one compiled_free_join(filters=...) per request, in arrival
            order. Warm steady state: cached tries, cached runner, one
            constant-parameterized executor — but one device dispatch
            per request.
  batched   JoinServeEngine at a fixed slot width: up to W co-template
            requests per vmapped dispatch, constants matrix (W, F) the
            only per-lane input.

Reported per mode: wall-clock queries/sec over the trace and per-request
latency at p50/p99 (a batched request's latency is its dispatch's wall
time — every rider pays the whole batch). The batched/serial throughput
ratio is the headline: the PR's acceptance floor is >= 2x at width >= 4.

Regime note: a batched (mask-mode) dispatch costs about one UNfiltered
query regardless of width, while a serial kill-mode query pays the
filtered cost — so batching wins exactly when W x filtered-cost exceeds
unfiltered-cost, i.e. the overhead-dominated point-lookup regime this
trace models (moderate key density, many small queries). Crank `dom`
far past `n`'s support and each constant matches a handful of rows:
serial kill mode then beats any fixed width — a real engine would route
such ultra-selective singletons to the unbatched path.

Rows land in the shared CSV; `joinperf.serving_batched_qps` carries
queries/sec in the value column (the `_qps` suffix flips the regression
gate to higher-is-better — see check_regression.py). Full runs append
serving_* fields to BENCH_join_perf.json.
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core import compiled_free_join
from repro.relational.relation import Relation
from repro.relational.schema import Atom, Query
from repro.serve import JoinServeEngine


def _trace(n=20_000, dom=2_000, n_tenants=16, n_queries=128, seed=0):
    """Shared triangle edges + a per-tenant alias spelling of the same
    query; constants drawn zipf-ish so some lanes are much heavier than
    others (the serving-realistic skew)."""
    rng = np.random.default_rng(seed)
    rels = {
        "R": Relation("R", {"x": rng.integers(0, dom, n), "y": rng.integers(0, dom, n)}),
        "S": Relation("S", {"y": rng.integers(0, dom, n), "z": rng.integers(0, dom, n)}),
        "T": Relation("T", {"z": rng.integers(0, dom, n), "x": rng.integers(0, dom, n)}),
    }
    tenants = []
    for t in range(n_tenants):
        # tenant t's spelling: same atoms, its own alias names and order
        atoms = [
            Atom("R", ("x", "y"), f"edges{t}_a"),
            Atom("S", ("y", "z"), f"edges{t}_b"),
            Atom("T", ("z", "x"), f"edges{t}_c"),
        ]
        order = rng.permutation(3)
        q = Query([atoms[i] for i in order])
        trels = {a.alias: rels[a.name] for a in atoms}
        tenants.append((f"tenant{t}", q, trels))
    consts = ((rng.zipf(1.3, n_queries) - 1) % dom).astype(int)
    trace = [
        (*tenants[i % n_tenants], {"x": int(consts[i])}) for i in range(n_queries)
    ]
    return rels, trace


def _percentile(xs, p):
    return float(np.percentile(np.asarray(xs, float), p)) if xs else float("nan")


def _run_serial(trace, repeats):
    def drain():
        lat, out = [], []
        for _tenant, q, trels, filters in trace:
            t0 = time.perf_counter()
            out.append(compiled_free_join(q, trels, agg="count", filters=filters))
            lat.append(time.perf_counter() - t0)
        return lat, out

    lat, out = drain()  # compile + warm caches
    best_wall, best_lat = float("inf"), lat
    for _ in range(repeats):
        t0 = time.perf_counter()
        lat, out2 = drain()
        wall = time.perf_counter() - t0
        if wall < best_wall:
            best_wall, best_lat = wall, lat
        assert out2 == out
    return best_wall, best_lat, out


def _run_batched(trace, width, repeats):
    def drain():
        eng = JoinServeEngine(slots=width)
        reqs = [
            eng.submit(q, trels, filters, tenant=tenant)
            for tenant, q, trels, filters in trace
        ]
        lat = []
        while eng.queue:
            t0 = time.perf_counter()
            retired = eng.step()
            dt = time.perf_counter() - t0
            lat.extend([dt] * len(retired))  # every rider pays the dispatch
        assert all(r.done and r.error is None for r in reqs)
        return lat, [r.result for r in reqs], eng

    lat, out, eng = drain()  # compile + warm caches
    best_wall, best_lat = float("inf"), lat
    for _ in range(repeats):
        t0 = time.perf_counter()
        lat, out2, eng = drain()
        wall = time.perf_counter() - t0
        if wall < best_wall:
            best_wall, best_lat = wall, lat
        assert out2 == out
    return best_wall, best_lat, out, eng


def run(repeats: int = 3, smoke: bool = False, width: int | None = None,
        path: str = "BENCH_join_perf.json"):
    if smoke:
        width = width or 8
        rels, trace = _trace(n=8_000, dom=1_500, n_tenants=4, n_queries=16)
    else:
        width = width or 16
        rels, trace = _trace()
    nq = len(trace)
    t_ser, lat_ser, out_ser = _run_serial(trace, repeats)
    t_bat, lat_bat, out_bat, eng = _run_batched(trace, width, repeats)
    assert out_bat == out_ser, "batched results diverge from serial"
    qps_ser = nq / t_ser
    qps_bat = nq / t_bat
    rows = [
        {"name": "joinperf.serving_serial", "us": t_ser / nq * 1e6,
         "derived": f"qps={qps_ser:.0f};p50_us={_percentile(lat_ser, 50) * 1e6:.0f};"
                    f"p99_us={_percentile(lat_ser, 99) * 1e6:.0f}"},
        {"name": "joinperf.serving_batched", "us": t_bat / nq * 1e6,
         "derived": f"qps={qps_bat:.0f};p50_us={_percentile(lat_bat, 50) * 1e6:.0f};"
                    f"p99_us={_percentile(lat_bat, 99) * 1e6:.0f};"
                    f"width={width};dispatches={eng.dispatches}"},
        {"name": "joinperf.serving_batched_qps", "us": qps_bat,
         "derived": f"speedup_vs_serial={qps_bat / qps_ser:.2f}x"},
    ]
    if smoke:
        return rows
    record = {
        "serving_trace": f"{nq} point-lookup triangle counts, "
                         f"{len({t for t, *_ in trace})} tenants, width {width}",
        "serving_serial_qps": qps_ser,
        "serving_batched_qps": qps_bat,
        "serving_speedup": qps_bat / qps_ser,
        "serving_serial_p50_us": _percentile(lat_ser, 50) * 1e6,
        "serving_serial_p99_us": _percentile(lat_ser, 99) * 1e6,
        "serving_batched_p50_us": _percentile(lat_bat, 50) * 1e6,
        "serving_batched_p99_us": _percentile(lat_bat, 99) * 1e6,
        "serving_dispatches": eng.dispatches,
    }
    import os

    if os.path.exists(path):
        with open(path) as f:
            full = json.load(f)
        full.update(record)
        with open(path, "w") as f:
            json.dump(full, f, indent=2)
            f.write("\n")
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
