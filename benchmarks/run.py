"""Benchmark suite entry point: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (and an aggregate at the end).

  PYTHONPATH=src python -m benchmarks.run [--only job,lsqb,...] [--smoke]

--smoke shrinks every suite to CI scale (tiny inputs, one repeat) so the
whole run finishes in seconds-to-a-minute instead of tens of minutes.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

from benchmarks.common import emit

SUITES = [
    "job",
    "lsqb",
    "colt",
    "vectorization",
    "robustness",
    "kernels",
    "join_perf",
    "serving",
    "streaming",
]

# per-suite kwargs for --smoke (every run() signature differs)
SMOKE_ARGS: dict[str, dict] = {
    "job": dict(scale=0.02, repeats=1),
    "lsqb": dict(sfs=(0.03,), repeats=1),
    "colt": dict(scale=0.02, repeats=1),
    "vectorization": dict(scale=0.005, repeats=1),
    "robustness": dict(scale=0.02, repeats=1),
    "kernels": dict(repeats=1),
    "join_perf": dict(smoke=True, repeats=1),
    "serving": dict(smoke=True, repeats=1),
    "streaming": dict(smoke=True, repeats=1),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated subset of " + ",".join(SUITES))
    ap.add_argument("--smoke", action="store_true", help="CI scale: tiny inputs, one repeat")
    args = ap.parse_args()
    picks = args.only.split(",") if args.only else SUITES
    all_rows = []
    for name in picks:
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        t0 = time.time()
        print(f"# --- {name} ---", file=sys.stderr, flush=True)
        rows = mod.run(**(SMOKE_ARGS.get(name, {}) if args.smoke else {}))
        print(f"# {name}: {time.time() - t0:.1f}s", file=sys.stderr, flush=True)
        all_rows.extend(rows)
    os.makedirs("benchmarks/results", exist_ok=True)
    emit(all_rows, path="benchmarks/results/latest.csv")


if __name__ == "__main__":
    main()
