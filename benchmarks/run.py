"""Benchmark suite entry point: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (and an aggregate at the end).

  PYTHONPATH=src python -m benchmarks.run [--only job,lsqb,...]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

from benchmarks.common import emit

SUITES = ["job", "lsqb", "colt", "vectorization", "robustness", "kernels", "join_perf"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated subset of " + ",".join(SUITES))
    args = ap.parse_args()
    picks = args.only.split(",") if args.only else SUITES
    all_rows = []
    for name in picks:
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        t0 = time.time()
        print(f"# --- {name} ---", file=sys.stderr, flush=True)
        rows = mod.run()
        print(f"# {name}: {time.time() - t0:.1f}s", file=sys.stderr, flush=True)
        all_rows.extend(rows)
    os.makedirs("benchmarks/results", exist_ok=True)
    emit(all_rows, path="benchmarks/results/latest.csv")


if __name__ == "__main__":
    main()
