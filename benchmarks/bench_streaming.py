"""Streaming ingest: delta-maintained standing query vs rebuild-per-batch.

The workload is a sustained update stream against a standing bushy count
query over a 4-relation chain R(a,b) S(b,c) T(c,d) U(d,e): batches of new
R rows arrive and the result must be current after every batch. Two ways
to stay current:

  delta     relcache.append through StandingQueryEngine.ingest — the
            cached trie absorbs each batch with ONE delta merge (sort the
            batch, splice the sorted run into the padded level buffers),
            and only the plan stages whose input fingerprints moved
            recompute: the T⋈U stage replays its cached device buffers
            every batch.
  rebuild   the pre-PR-9 discipline, run on a SEPARATE relation set with
            no mutation state: each batch replaces R's host columns with
            np.concatenate'd copies (so every identity-keyed cache
            misses, as it would for any out-of-band mutation) and a warm
            compiled_free_join re-sorts and rebuilds from scratch.

Both modes ingest the identical batch schedule from the identical start
state and must report identical counts after every batch. Each mode runs
ONE growing stream per repeat: the first `warm` batches are untimed (they
pay delta-path trace warmup — cold-trie adoption, the capacity-bucket
jump — and the executor growth both modes share), then the remaining
batches are timed as the sustained steady state. The warmup sizes below
are chosen so the timed appends stay inside one capacity bucket: the
delta path's shapes are then static, which is exactly the padding
contract's point. The rebuild path has no such bucket — every batch
shifts every array shape, so it pays XLA retracing ON TOP of the O(N)
re-sort, and that is the honest cost of rebuild-per-batch in a compiled
setting, not an artifact.

The headline is sustained updates/sec (timed batches per wall second;
rows/sec in the derived column) and the delta/rebuild ratio — the PR's
acceptance floor is >= 2x. `joinperf.streaming_delta_qps` /
`joinperf.streaming_rebuild_qps` carry updates/sec in the value column
(the `_qps` suffix flips the regression gate to higher-is-better — see
check_regression.py). Full runs append streaming_* fields to
BENCH_join_perf.json.
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core import compiled_free_join
from repro.core.api import ExecOptions
from repro.core.compiled import TRIE_CACHE
from repro.core.plan import BinaryPlan
from repro.relational.relation import Relation
from repro.relational.schema import Atom, Query
from repro.serve import StandingQueryEngine


def _workload(n=60_000, dom=4_000, batch=2_048, warm=3, n_meas=12, seed=0):
    """Base columns plus the fixed batch schedule (all np.int32). Both
    modes build their own Relation objects from copies of these arrays so
    neither can warm the other's identity-keyed caches. `warm` leading
    batches are untimed; they are sized to push the delta path past its
    one capacity-bucket jump so the `n_meas` timed batches keep every
    shape static."""
    rng = np.random.default_rng(seed)
    q = Query(
        [Atom("R", ("a", "b")), Atom("S", ("b", "c")), Atom("T", ("c", "d")), Atom("U", ("d", "e"))]
    )
    a = {at.alias: at for at in q.atoms}
    tree = BinaryPlan(BinaryPlan(a["R"], a["S"]), BinaryPlan(a["T"], a["U"]))
    cols = {
        at.alias: {v: rng.integers(0, dom, n).astype(np.int32) for v in at.vars} for at in q.atoms
    }
    deltas = [
        {v: rng.integers(0, dom, batch).astype(np.int32) for v in ("a", "b")}
        for _ in range(warm + n_meas)
    ]
    return q, tree, cols, deltas, warm


def _mk_rels(cols):
    return {
        alias: Relation(alias, {v: c.copy() for v, c in cs.items()}) for alias, cs in cols.items()
    }


def _run_delta(q, tree, cols, deltas, warm, repeats):
    best, out = float("inf"), None
    for rep in range(repeats):
        rels = _mk_rels(cols)
        eng = StandingQueryEngine(options=ExecOptions())
        sq = eng.register(q, rels, agg="count", plan_tree=tree)
        for d in deltas[:warm]:
            eng.ingest(rels["R"], d)
        results = []
        t0 = time.perf_counter()
        for d in deltas[warm:]:
            eng.ingest(rels["R"], d)
            results.append(sq.result)
        wall = time.perf_counter() - t0
        if out is None:
            out = results
        else:
            assert results == out, "delta stream results diverged across repeats"
        best = min(best, wall)
    return best, out, eng


def _run_rebuild(q, tree, cols, deltas, warm, repeats):
    best, out = float("inf"), None
    for rep in range(repeats):
        rels = _mk_rels(cols)
        compiled_free_join(q, rels, tree, agg="count")  # warm the pre-stream state

        def ingest(d):
            r = rels["R"]
            for v in r.schema:
                r.columns[v] = np.concatenate([r.columns[v], d[v]])
            r.num_rows = len(r.columns[r.schema[0]])
            return compiled_free_join(q, rels, tree, agg="count")

        for d in deltas[:warm]:
            ingest(d)
        results = []
        t0 = time.perf_counter()
        for d in deltas[warm:]:
            results.append(ingest(d))
        wall = time.perf_counter() - t0
        if out is None:
            out = results
        else:
            assert results == out, "rebuild stream results diverged across repeats"
        best = min(best, wall)
    return best, out


def run(repeats: int = 3, smoke: bool = False, path: str = "BENCH_join_perf.json"):
    if smoke:
        q, tree, cols, deltas, warm = _workload(
            n=3_000, dom=400, batch=512, warm=3, n_meas=6
        )
    else:
        q, tree, cols, deltas, warm = _workload()
    nb, batch = len(deltas) - warm, len(next(iter(deltas[0].values())))
    t_delta, out_delta, eng = _run_delta(q, tree, cols, deltas, warm, repeats)
    t_reb, out_reb = _run_rebuild(q, tree, cols, deltas, warm, repeats)
    assert out_delta == out_reb, "delta maintenance diverges from rebuild-per-batch"
    ups_delta = nb / t_delta
    ups_reb = nb / t_reb
    rows = [
        {"name": "joinperf.streaming_delta", "us": t_delta / nb * 1e6,
         "derived": f"ups={ups_delta:.1f};rows_per_s={ups_delta * batch:.0f};"
                    f"stages_skipped={eng.stages_skipped}"},
        {"name": "joinperf.streaming_rebuild", "us": t_reb / nb * 1e6,
         "derived": f"ups={ups_reb:.1f};rows_per_s={ups_reb * batch:.0f}"},
        {"name": "joinperf.streaming_delta_qps", "us": ups_delta,
         "derived": f"speedup_vs_rebuild={ups_delta / ups_reb:.2f}x"},
        {"name": "joinperf.streaming_rebuild_qps", "us": ups_reb,
         "derived": f"batch={batch};n_meas={nb};warm={warm}"},
    ]
    if smoke:
        return rows
    record = {
        "streaming_trace": f"{nb} timed batches x {batch} rows into R of a 4-chain bushy "
                           f"count ({warm} warmup batches)",
        "streaming_delta_ups": ups_delta,
        "streaming_rebuild_ups": ups_reb,
        "streaming_speedup": ups_delta / ups_reb,
        "streaming_delta_merges": TRIE_CACHE.delta_merges,
        "streaming_stages_skipped": eng.stages_skipped,
    }
    import os

    if os.path.exists(path):
        with open(path) as f:
            full = json.load(f)
        full.update(record)
        with open(path, "w") as f:
            json.dump(full, f, indent=2)
            f.write("\n")
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
