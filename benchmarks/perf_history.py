"""PR-over-PR perf trajectory: a committed history of bench-smoke rows.

BENCH_join_perf.json keeps only the latest full-scale record; the CI
regression gate only answers "did THIS run slip >2x". Neither shows the
trend. This module maintains ``benchmarks/results/history.csv`` —
``commit,name,us`` rows, one block per commit — and renders it as a
markdown trend table for the CI job summary.

  PYTHONPATH=src python -m benchmarks.perf_history append \
      benchmarks/results/latest.csv benchmarks/results/history.csv
  PYTHONPATH=src python -m benchmarks.perf_history table \
      benchmarks/results/history.csv

``append`` keys the rows by --commit (default: git short HEAD) and
replaces any existing block for the same commit, so re-runs don't
duplicate. The committed file grows one block per PR (append locally from
a bench-smoke run, commit alongside the change); CI appends its own run
ephemerally so the job-summary table always ends with the commit under
test. Rows named ``*_qps`` are throughputs (higher is better), everything
else is µs per call (lower is better); the Δ column colors accordingly.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys

from benchmarks.check_regression import read_csv

HEADER = "commit,name,us\n"


def read_history(path: str) -> tuple[list[str], dict[str, dict[str, float]]]:
    """-> (commits in first-appearance order, {commit: {name: us}})."""
    commits: list[str] = []
    data: dict[str, dict[str, float]] = {}
    if not os.path.exists(path):
        return commits, data
    with open(path) as f:
        header = f.readline()
        assert header.startswith("commit,"), f"unexpected history header: {header!r}"
        for line in f:
            parts = line.rstrip("\n").split(",", 2)
            if len(parts) != 3 or not parts[0]:
                continue
            sha, name, us = parts
            if sha not in data:
                commits.append(sha)
                data[sha] = {}
            data[sha][name] = float(us)
    return commits, data


def append(csv_path: str, history_path: str, commit: str | None, prefix: str) -> int:
    commit = commit or subprocess.run(
        ["git", "rev-parse", "--short", "HEAD"], capture_output=True, text=True
    ).stdout.strip()
    assert commit, "no commit id: pass --commit or run inside a git checkout"
    rows = {k: v for k, v in read_csv(csv_path).items() if k.startswith(prefix)}
    assert rows, f"no rows with prefix {prefix!r} in {csv_path}"
    commits, data = read_history(history_path)
    if commit not in data:
        commits.append(commit)
    data[commit] = rows  # same commit re-run: replace, don't duplicate
    os.makedirs(os.path.dirname(history_path) or ".", exist_ok=True)
    with open(history_path, "w") as f:
        f.write(HEADER)
        for sha in commits:
            for name, us in sorted(data[sha].items()):
                f.write(f"{sha},{name},{us:.1f}\n")
    print(f"{history_path}: {len(rows)} rows recorded for {commit} "
          f"({len(commits)} commits tracked)")
    return 0


def _fmt(us: float | None, qps: bool) -> str:
    if us is None:
        return "—"
    return f"{us:,.0f} qps" if qps else f"{us:,.0f} µs"


def table(history_path: str, last: int, prefix: str) -> int:
    commits, data = read_history(history_path)
    if not commits:
        print(f"(no perf history at {history_path})")
        return 0
    commits = commits[-last:]
    names = sorted({n for sha in commits for n in data[sha] if n.startswith(prefix)})
    out = ["### Perf trend (bench-smoke, µs per call; `*_qps` rows are throughput)", ""]
    out.append("| bench | " + " | ".join(commits) + " | Δ last |")
    out.append("|---" + "|---:" * (len(commits) + 1) + "|")
    for name in names:
        qps = name.endswith("_qps")
        vals = [data[sha].get(name) for sha in commits]
        delta = "—"
        present = [v for v in vals if v is not None]
        if len(present) >= 2 and present[-2]:
            pct = (present[-1] - present[-2]) / present[-2] * 100.0
            better = pct > 0 if qps else pct < 0
            delta = f"{pct:+.1f}% {'✅' if better else '⚠️' if abs(pct) > 10 else ''}".rstrip()
        out.append(
            f"| {name} | " + " | ".join(_fmt(v, qps) for v in vals) + f" | {delta} |"
        )
    print("\n".join(out))
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)
    a = sub.add_parser("append", help="record a bench-smoke CSV under a commit id")
    a.add_argument("csv")
    a.add_argument("history")
    a.add_argument("--commit", default=None)
    a.add_argument("--prefix", default="joinperf.")
    t = sub.add_parser("table", help="render the markdown trend table")
    t.add_argument("history")
    t.add_argument("--last", type=int, default=5)
    t.add_argument("--prefix", default="joinperf.")
    args = ap.parse_args()
    if args.cmd == "append":
        return append(args.csv, args.history, args.commit, args.prefix)
    return table(args.history, args.last, args.prefix)


if __name__ == "__main__":
    sys.exit(main())
