"""§Perf hillclimb cell 3: the compiled Free Join engine itself (the
paper-representative pair). Wall-clock on CPU (the join engine is the one
component that genuinely runs here), jit-compiled, excluding compile.

Part 1 — hillclimb iterations on the triangle count over zipf-skewed edges
(hypothesis -> change -> measure, EXPERIMENTS.md §Perf):
  J0 baseline            capacities 4M, probe budget 32
  J1 probe budget 8      probe loop is 32 unrolled gather+compare rounds;
                         load factor <= 0.5 => clusters are short; 8 rounds
                         should cut probe work ~4x if probes dominate
  J2 tight capacities    right-size frontier buffers from cardinality
                         estimates (expansion + mask work scales with
                         capacity, not with live rows)
  J3 J1+J2 combined

Part 2 — the planned path vs the eager engine on a low-selectivity star
query (a selective probe kills most frontier lanes early):
  eager                  api.free_join (numpy COLT engine)
  compiled_nocompact     AdaptiveExecutor, planner capacities, no compaction
  compiled_compact       same + frontier compaction at the planner-chosen
                         point (mid-node, right after the selective probe).
                         This is the COLD per-call cost: tries rebuilt
                         in-graph on every call.
  compiled_warm          the same executor fed prebuilt tries from the
                         cross-call TRIE_CACHE (run_relations): the
                         steady-state serving cost, probe work only. The
                         build/probe split is also timed separately (the
                         jit'd build program alone vs the warm probe call)
                         and recorded in BENCH_join_perf.json.

Part 3 — the compiled-distributed path on the same star query: SpmdCounter
(hypercube partition + shard_map + psum, planner capacities per shard) on a
2- and 4-shard mesh of fake CPU devices. Runs in a subprocess so the forced
device count never leaks into this process's jax backend.

Part 4 — bushy plans (PR 4): a three-stage bushy tree over a six-relation
path query, eager vs the PR 3 hybrid (non-root stages on the eager host
engine per call, root compiled) vs the fully-compiled chain (every stage
on device inside one AdaptiveExecutor call).

Part 5 — plan choice (PR 7): greedy left-deep (optimize_level=0) vs the
cost-based bushy enumeration (optimize_level=2) on a four-relation chain
with selective end joins and a dense middle join. The greedy search can
only extend left-deep, so it drags the dense A⋈B⋈C intermediate through
the rest of the plan; the DP brackets it as (A⋈B)⋈(C⋈D) and the device
cost model picks that. Warm steady state (runners built once, tries
cached), interleaved timing.

The rows also land in BENCH_join_perf.json (repo root) so the perf
trajectory of the compiled path is tracked PR-over-PR.
"""
from __future__ import annotations

import json
import time

import numpy as np

import jax

from benchmarks.common import timeit
from repro.core import ExecOptions, binary2fj, factor, free_join
from repro.core.capacity import plan_capacities
from repro.core.compiled import AdaptiveExecutor, make_count_fn, relations_to_cols
from repro.core.plan import BinaryPlan
from repro.relational.relation import Relation
from repro.relational.schema import Atom, Query, triangle_query


def _data(n=200_000, dom=30_000, seed=0):
    rng = np.random.default_rng(seed)
    q = triangle_query()
    rels = {}
    for a in q.atoms:
        z = ((rng.zipf(1.5, n) - 1) % dom)
        perm = rng.permutation(dom)
        rels[a.alias] = Relation(
            a.alias, {a.vars[0]: perm[z], a.vars[1]: rng.integers(0, dom, n)}
        )
    return q, rels


def _lowsel_data(n=600_000, dom=30_000, sel=0.02, seed=0):
    """Star Q(x,y,a,b) :- R(x,y), S(y,a), T(y,b) where S covers only a
    `sel` fraction of the y domain. The factored plan probes S then T in
    one node; the S probe kills ~98% of the frontier, so without compaction
    the T probe (budget x gather rounds per lane) and both later factorized
    folds drag every dead lane along — the compaction sweet spot."""
    rng = np.random.default_rng(seed)
    q = Query([Atom("R", ("x", "y")), Atom("S", ("y", "a")), Atom("T", ("y", "b"))])
    ny = max(1, int(dom * sel))
    y_live = rng.choice(dom, ny, replace=False)
    rels = {
        "R": Relation("R", {"x": rng.integers(0, dom, n), "y": rng.integers(0, dom, n)}),
        "S": Relation("S", {"y": y_live[rng.integers(0, ny, ny)],
                            "a": rng.integers(0, dom, ny)}),
        "T": Relation("T", {"y": rng.integers(0, dom, n // 10),
                            "b": rng.integers(0, dom, n // 10)}),
    }
    return q, rels


def _run(q, rels, caps, budget, repeats=3):
    import jax.numpy as jnp

    fj = factor(binary2fj(q.atoms, q))
    fn = jax.jit(make_count_fn(fj, caps, impl="jnp", budget=budget))
    cols = {
        a.alias: {v: jnp.asarray(rels[a.alias].columns[v], jnp.int32) for v in a.vars}
        for a in q.atoms
    }
    count, ovf = fn(cols)  # compile + 1st run
    assert not bool(ovf), "capacity overflow"
    t, _ = timeit(lambda: jax.block_until_ready(fn(cols)), repeats=repeats, warmup=1)
    return t, int(count)


def _run_adaptive(q, rels, repeats, compact_threshold):
    fj = factor(binary2fj(q.atoms, q))
    planned = plan_capacities(fj, rels, compact_threshold=compact_threshold)
    ex = AdaptiveExecutor(fj, planned, agg="count")
    cols = relations_to_cols(fj, rels)
    count = int(ex(cols))  # compile (+ any overflow growth) + 1st run
    t, _ = timeit(lambda: jax.block_until_ready(ex(cols)), repeats=repeats, warmup=1)
    return t, count, ex, planned


def _time_build_program(ex, rels, repeats):
    """Wall time of the jit'd trie build program alone: every base
    relation's trie rebuilt from its (cached) device columns, bypassing the
    trie cache — the per-call cost the warm path amortizes away."""
    from repro.core import compiled as C

    plans = []
    for a, lo in sorted(ex._alias_lops.items()):
        if lo is None:
            continue
        rel = rels[a]
        dev = C.device_columns(rel)
        flat = tuple(v for lv in lo.levels for v in lv)
        used = {v: dev[v] for v in flat}
        plans.append((used, lo, C.TRIE_CACHE._key_bits(rel, flat)))

    def build_all():
        return [
            C._build_trie_jit(used, lo, ex.impl, ex.budget, kb, None, 0)
            for used, lo, kb in plans
        ]

    t, _ = timeit(lambda: jax.block_until_ready(build_all()), repeats=repeats, warmup=1)
    return t


def run(repeats: int = 3, smoke: bool = False):
    q, rels = _data(n=10_000, dom=3_000) if smoke else _data()
    cap = 1 << 17 if smoke else 1 << 22
    tight = [1 << 14, 1 << 16, 1 << 16, 1 << 16] if smoke else [1 << 19, 1 << 21, 1 << 21, 1 << 21]
    rows = []
    # J0
    t0, c0 = _run(q, rels, [cap] * 4, 32, repeats)
    rows.append({"name": "joinperf.J0_baseline", "us": t0 * 1e6, "derived": f"count={c0}"})
    # J1: probe budget 8
    t1, c1 = _run(q, rels, [cap] * 4, 8, repeats)
    assert c1 == c0
    rows.append({"name": "joinperf.J1_budget8", "us": t1 * 1e6,
                 "derived": f"speedup_vs_J0={t0 / t1:.2f}x"})
    # J2: tight capacities (estimate-sized, x2 safety)
    t2, c2 = _run(q, rels, tight, 32, repeats)
    assert c2 == c0
    rows.append({"name": "joinperf.J2_tight_caps", "us": t2 * 1e6,
                 "derived": f"speedup_vs_J0={t0 / t2:.2f}x"})
    # J3: both
    t3, c3 = _run(q, rels, tight, 8, repeats)
    assert c3 == c0
    rows.append({"name": "joinperf.J3_combined", "us": t3 * 1e6,
                 "derived": f"speedup_vs_J0={t0 / t3:.2f}x"})
    rows.extend(run_compiled_vs_eager(repeats=repeats, smoke=smoke))
    rows.extend(run_distributed(repeats=repeats, smoke=smoke))
    rows.extend(run_bushy(repeats=repeats, smoke=smoke))
    rows.extend(run_planner(repeats=repeats, smoke=smoke))
    return rows


def run_compiled_vs_eager(
    repeats: int = 3, smoke: bool = False, path: str = "BENCH_join_perf.json"
):
    """Eager vs planned-compiled (with/without compaction) on the
    low-selectivity star query; writes the BENCH_join_perf.json perf record
    (full runs only — smoke numbers don't overwrite the trajectory)."""
    q, rels = _lowsel_data(n=30_000, dom=3_000) if smoke else _lowsel_data()
    te, ce = timeit(lambda: free_join(q, rels, agg="count"), repeats=repeats, warmup=1)
    tn, cn, _, _ = _run_adaptive(q, rels, repeats, compact_threshold=0.0)  # never compact
    tc, cc, ex, planned = _run_adaptive(q, rels, repeats, compact_threshold=0.25)
    # warm (cached-trie) steady state: run_relations serves prebuilt tries
    # from the cross-call cache — pure probe cost per call
    cw = ex.run_relations(rels)  # cold build into the cache + compile
    tw, _ = timeit(lambda: ex.run_relations(rels), repeats=repeats, warmup=1)
    tb = _time_build_program(ex, rels, repeats)
    assert ce == cn == cc == cw, (ce, cn, cc, cw)
    # check the planner's output: adaptive growth may legitimately disable
    # an under-targeted compaction at run time
    assert any(t is not None for t in planned.compact_to), "expected a compaction node"
    rows = [
        {"name": "joinperf.eager_lowsel", "us": te * 1e6, "derived": f"count={ce}"},
        {"name": "joinperf.compiled_nocompact_lowsel", "us": tn * 1e6,
         "derived": f"speedup_vs_eager={te / tn:.2f}x"},
        {"name": "joinperf.compiled_compact_lowsel", "us": tc * 1e6,
         "derived": f"speedup_vs_nocompact={tn / tc:.2f}x;plan={ex.cap_plan}"},
        {"name": "joinperf.compiled_warm_lowsel", "us": tw * 1e6,
         "derived": f"speedup_vs_cold={tc / tw:.2f}x;build_us={tb * 1e6:.0f}"},
    ]
    if smoke:
        return rows
    record = {
        "bench": "join_perf.compiled_vs_eager",
        "query": "star R(x,y),S(y,a),T(y,b), 2% probe selectivity",
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "count": ce,
        "eager_us": te * 1e6,
        "compiled_nocompact_us": tn * 1e6,
        "compiled_compact_us": tc * 1e6,
        "compact_speedup_vs_nocompact": tn / tc,
        "compiled_warm_us": tw * 1e6,
        "warm_speedup_vs_cold": tc / tw,
        "build_us": tb * 1e6,
        "probe_us": tw * 1e6,
        "capacity_plan": str(ex.cap_plan),
        "retries": ex.retries,
    }
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    return rows


def _bushy_data(n=600_000, dom=30_000, sel=0.02, seed=0):
    """Bushy tree (A ⋈ B) ⋈ ((R ⋈ S) ⋈ T): the non-root stage is the
    low-selectivity star of _lowsel_data (S covers a `sel` fraction of the
    y domain, so ~98% of the stage frontier dies at the S probe) — the
    regime where the compiled path beats the eager engine. The hybrid
    re-runs that star on the eager engine (COLT builds + host
    materialization) every call; the chain runs it compiled, with the
    output buffer squeezed by the planner's compact_output point."""
    rng = np.random.default_rng(seed)
    atoms = [
        Atom("A", ("u", "v")),
        Atom("B", ("v", "x")),
        Atom("R", ("x", "y")),
        Atom("S", ("y", "a")),
        Atom("T", ("y", "b")),
    ]
    q = Query(atoms)
    tree = BinaryPlan(
        BinaryPlan(atoms[0], atoms[1]),
        BinaryPlan(BinaryPlan(atoms[2], atoms[3]), atoms[4]),
    )
    ny = max(1, int(dom * sel))
    y_live = rng.choice(dom, ny, replace=False)
    m = n // 15
    rels = {
        "A": Relation("A", {"u": rng.integers(0, dom, m), "v": rng.integers(0, dom, m)}),
        "B": Relation("B", {"v": rng.integers(0, dom, m), "x": rng.integers(0, dom, m)}),
        "R": Relation("R", {"x": rng.integers(0, dom, n), "y": rng.integers(0, dom, n)}),
        "S": Relation("S", {"y": y_live[rng.integers(0, ny, ny)], "a": rng.integers(0, dom, ny)}),
        "T": Relation(
            "T", {"y": rng.integers(0, dom, n // 10), "b": rng.integers(0, dom, n // 10)}
        ),
    }
    return q, tree, rels


def run_bushy(repeats: int = 3, smoke: bool = False, path: str = "BENCH_join_perf.json"):
    """Part 4: eager vs PR 3 hybrid vs fully-compiled chain on a bushy plan.
    Steady state for both compiled variants (runners built once, compile
    excluded); the hybrid re-runs its eager non-root stages every call —
    that is exactly the per-query cost the chain removes. Full runs append
    bushy_* fields to the BENCH_join_perf.json record."""
    from repro.core import compiled_free_join, engine
    from repro.core.api import _stage_plans, _trie_modes

    q, tree, rels = _bushy_data(n=30_000, dom=3_000) if smoke else _bushy_data()
    stages = _stage_plans(q, tree)
    assert len(stages) == 2, "the tree must decompose into stage + root"

    # PR 3 hybrid: cached compiled root, eager stages re-run per call
    info_h = {}
    ch = compiled_free_join(q, rels, tree, agg="count", chain_stages=False, info=info_h)
    hybrid_runner = info_h["runner"]

    def hybrid_once():
        rels2 = dict(rels)
        for name, fj in stages[:-1]:
            bound, mult = engine.execute(fj, rels2, mode=_trie_modes(fj, "colt"), agg=None)
            rels2[name] = Relation(name, engine.materialize(bound, mult, fj.query.head))
        # faithful hybrid baseline: per-call in-graph builds, no trie cache
        return hybrid_runner.run_relations(rels2, reuse_tries=False)

    # fully-compiled chain: one on-device program for every stage
    info_c = {}
    cc = compiled_free_join(q, rels, tree, agg="count", info=info_c)
    chain_runner = info_c["runner"]

    # interleaved best-of-N: the three paths alternate inside each round so
    # machine drift (frequency scaling, allocator state) hits them equally
    # — sequential per-path timing swings the comparison by 30% run to run
    paths = [
        lambda: free_join(q, rels, tree, agg="count"),
        hybrid_once,
        lambda: chain_runner.run_relations(rels),
    ]
    counts = [fn() for fn in paths]  # warmup
    best = [float("inf")] * 3
    for _ in range(max(3, repeats)):
        for i, fn in enumerate(paths):
            t0 = time.perf_counter()
            counts[i] = fn()
            best[i] = min(best[i], time.perf_counter() - t0)
    te, th, tc = best
    ce, ch2, cc2 = counts
    assert ce == ch == ch2 == cc == cc2, (ce, ch, ch2, cc, cc2)

    rows = [
        {"name": "joinperf.bushy_eager", "us": te * 1e6, "derived": f"count={ce}"},
        {"name": "joinperf.bushy_hybrid", "us": th * 1e6,
         "derived": f"speedup_vs_eager={te / th:.2f}x"},
        {"name": "joinperf.bushy_chained", "us": tc * 1e6,
         "derived": f"speedup_vs_hybrid={th / tc:.2f}x;plan={info_c['cap_plan']}"},
    ]
    if smoke:
        return rows
    record = {
        "bushy_query": "(A join B) join lowsel-star(R,S,T), 2% S selectivity",
        "bushy_count": ce,
        "bushy_eager_us": te * 1e6,
        "bushy_hybrid_us": th * 1e6,
        "bushy_chained_us": tc * 1e6,
        "bushy_chained_speedup_vs_hybrid": th / tc,
        "bushy_chain_plan": str(info_c["cap_plan"]),
        "bushy_retries": info_c["retries"],
    }
    import os

    if os.path.exists(path):
        with open(path) as f:
            full = json.load(f)
        full.update(record)
        with open(path, "w") as f:
            json.dump(full, f, indent=2)
            f.write("\n")
    return rows


def _selective_ends_chain(n=50_000, dense_dom=1_000, sel_dom=None, seed=0):
    """Chain A(a,b) B(b,c) C(c,d) D(d,e): b and d join keys as selective as
    the relations are wide (|A⋈B| ~ |A|), c dense (|B⋈C| ~ n^2/dense_dom).
    The left-deep intermediate A⋈B⋈C is ~n/dense_dom times the bushy
    stages' — the workload the enumeration exists for."""
    rng = np.random.default_rng(seed)
    sel_dom = sel_dom or n
    rels = {
        "A": Relation("A", {"a": rng.integers(0, n, n), "b": rng.integers(0, sel_dom, n)}),
        "B": Relation("B", {"b": rng.integers(0, sel_dom, n), "c": rng.integers(0, dense_dom, n)}),
        "C": Relation("C", {"c": rng.integers(0, dense_dom, n), "d": rng.integers(0, sel_dom, n)}),
        "D": Relation("D", {"d": rng.integers(0, sel_dom, n), "e": rng.integers(0, n, n)}),
    }
    q = Query(
        [Atom("A", ("a", "b")), Atom("B", ("b", "c")), Atom("C", ("c", "d")), Atom("D", ("d", "e"))]
    )
    return q, rels


def run_planner(repeats: int = 3, smoke: bool = False, path: str = "BENCH_join_perf.json"):
    """Part 5: greedy left-deep vs cost-based bushy enumeration, warm
    steady state. Both plans are chosen by the optimizer (no hand-written
    tree); full runs append plan_* fields to BENCH_join_perf.json."""
    from repro.core import compiled_free_join
    from repro.core import relcache

    q, rels = _selective_ends_chain(n=5_000, dense_dom=100) if smoke else _selective_ends_chain()
    relcache.FEEDBACK.clear()  # cold-plan comparison: estimates only
    runners, trees = {}, {}
    for name, level in (("greedy", 0), ("enumerated", 2)):
        info = {}
        compiled_free_join(
            q, rels, agg="count", options=ExecOptions(optimize_level=level), info=info
        )
        runners[name], trees[name] = info["runner"], info["plan_tree"]
    assert str(trees["greedy"]) != str(trees["enumerated"]), (
        "the enumeration found nothing beyond greedy on its showcase workload"
    )
    # interleaved best-of-N (see run_bushy): warm probe cost only
    paths = [
        lambda: runners["greedy"].run_relations(rels, reuse_tries=True),
        lambda: runners["enumerated"].run_relations(rels, reuse_tries=True),
    ]
    counts = [fn() for fn in paths]  # warmup
    assert counts[0] == counts[1], counts
    best = [float("inf")] * 2
    for _ in range(max(3, repeats)):
        for i, fn in enumerate(paths):
            t0 = time.perf_counter()
            counts[i] = fn()
            best[i] = min(best[i], time.perf_counter() - t0)
    tg, tn = best
    rows = [
        {"name": "joinperf.plan_greedy", "us": tg * 1e6, "derived": f"count={counts[0]}"},
        {"name": "joinperf.plan_enumerated", "us": tn * 1e6,
         "derived": f"speedup_vs_greedy={tg / tn:.2f}x"},
    ]
    if smoke:
        return rows
    record = {
        "plan_query": "chain A(a,b) B(b,c) C(c,d) D(d,e), dense c, selective b/d",
        "plan_count": counts[0],
        "plan_greedy_us": tg * 1e6,
        "plan_enumerated_us": tn * 1e6,
        "plan_enumerated_speedup": tg / tn,
        "plan_greedy_tree": str(trees["greedy"]),
        "plan_enumerated_tree": str(trees["enumerated"]),
    }
    import os

    if os.path.exists(path):
        with open(path) as f:
            full = json.load(f)
        full.update(record)
        with open(path, "w") as f:
            json.dump(full, f, indent=2)
            f.write("\n")
    return rows


DIST_SCRIPT = r"""
import json, sys
import numpy as np, jax
from benchmarks.bench_join_perf import _lowsel_data
from benchmarks.common import timeit
from repro.core import binary2fj, factor
from repro.core.distributed import SpmdCounter
shards, n, dom, repeats = map(int, sys.argv[1:5])
q, rels = _lowsel_data(n=n, dom=dom)
fj = factor(binary2fj(q.atoms, q))
mesh = jax.make_mesh((shards,), ("data",))
ctr = SpmdCounter(q, rels, fj, None, mesh)  # planner capacities per shard
count = ctr()  # compile (+ any overflow growth) + 1st run
t, _ = timeit(lambda: ctr(), repeats=repeats, warmup=1)
print("DIST " + json.dumps({"us": t * 1e6, "count": count, "shards": shards,
                            "retries": ctr.retries, "cap_plan": str(ctr.cap_plan)}))
"""


def run_distributed(
    repeats: int = 3, smoke: bool = False, path: str = "BENCH_join_perf.json"
):
    """Compiled-distributed star-query rows (see module docstring, part 3).
    Each shard count runs in its own subprocess with that many fake CPU
    devices; full runs append spmd_* fields to the BENCH_join_perf.json
    record written by run_compiled_vs_eager."""
    import os
    import subprocess
    import sys as _sys

    n, dom = (30_000, 3_000) if smoke else (600_000, 30_000)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    rows, record = [], {}
    for shards in (2,) if smoke else (2, 4):
        env = {
            **os.environ,
            "XLA_FLAGS": f"--xla_force_host_platform_device_count={shards} "
            + os.environ.get("XLA_FLAGS", ""),
            "PYTHONPATH": "src" + os.pathsep + os.environ.get("PYTHONPATH", ""),
        }
        res = subprocess.run(
            [_sys.executable, "-c", DIST_SCRIPT, str(shards), str(n), str(dom), str(repeats)],
            capture_output=True, text=True, env=env, timeout=1200, cwd=root,
        )
        out = [ln for ln in res.stdout.splitlines() if ln.startswith("DIST ")]
        assert out, res.stderr[-2000:]
        rec = json.loads(out[-1][5:])
        rows.append({
            "name": f"joinperf.spmd_star_{shards}shard", "us": rec["us"],
            "derived": f"count={rec['count']};retries={rec['retries']};plan={rec['cap_plan']}",
        })
        record[f"spmd_{shards}shard_us"] = rec["us"]
        record[f"spmd_{shards}shard_count"] = rec["count"]
        record[f"spmd_{shards}shard_retries"] = rec["retries"]
    if not smoke and os.path.exists(path):
        with open(path) as f:
            full = json.load(f)
        full.update(record)
        with open(path, "w") as f:
            json.dump(full, f, indent=2)
            f.write("\n")
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
