"""§Perf hillclimb cell 3: the compiled Free Join engine itself (the
paper-representative pair). Wall-clock on CPU (the join engine is the one
component that genuinely runs here), jit-compiled, excluding compile:
triangle count over zipf-skewed edges.

Iterations (hypothesis -> change -> measure, EXPERIMENTS.md §Perf):
  J0 baseline            capacities 4M, probe budget 32
  J1 probe budget 8      probe loop is 32 unrolled gather+compare rounds;
                         load factor <= 0.5 => clusters are short; 8 rounds
                         should cut probe work ~4x if probes dominate
  J2 tight capacities    right-size frontier buffers from cardinality
                         estimates (expansion + mask work scales with
                         capacity, not with live rows)
  J3 J1+J2 combined
"""
from __future__ import annotations

import time

import numpy as np

import jax

from benchmarks.common import timeit
from repro.core import binary2fj, factor
from repro.core.compiled import make_count_fn
from repro.relational.relation import Relation
from repro.relational.schema import triangle_query


def _data(n=200_000, dom=30_000, seed=0):
    rng = np.random.default_rng(seed)
    q = triangle_query()
    rels = {}
    for a in q.atoms:
        z = ((rng.zipf(1.5, n) - 1) % dom)
        perm = rng.permutation(dom)
        rels[a.alias] = Relation(
            a.alias, {a.vars[0]: perm[z], a.vars[1]: rng.integers(0, dom, n)}
        )
    return q, rels


def _run(q, rels, caps, budget, repeats=3):
    import jax.numpy as jnp

    fj = factor(binary2fj(q.atoms, q))
    fn = jax.jit(make_count_fn(fj, caps, impl="jnp", budget=budget))
    cols = {
        a.alias: {v: jnp.asarray(rels[a.alias].columns[v], jnp.int32) for v in a.vars}
        for a in q.atoms
    }
    count, ovf = fn(cols)  # compile + 1st run
    assert not bool(ovf), "capacity overflow"
    t, _ = timeit(lambda: jax.block_until_ready(fn(cols)), repeats=repeats, warmup=1)
    return t, int(count)


def run(repeats: int = 3):
    q, rels = _data()
    rows = []
    # J0
    t0, c0 = _run(q, rels, [1 << 22] * 4, 32, repeats)
    rows.append({"name": "joinperf.J0_baseline", "us": t0 * 1e6, "derived": f"count={c0}"})
    # J1: probe budget 8
    t1, c1 = _run(q, rels, [1 << 22] * 4, 8, repeats)
    assert c1 == c0
    rows.append({"name": "joinperf.J1_budget8", "us": t1 * 1e6,
                 "derived": f"speedup_vs_J0={t0 / t1:.2f}x"})
    # J2: tight capacities (estimate-sized, x2 safety)
    caps = [1 << 19, 1 << 21, 1 << 21, 1 << 21]
    t2, c2 = _run(q, rels, caps, 32, repeats)
    assert c2 == c0
    rows.append({"name": "joinperf.J2_tight_caps", "us": t2 * 1e6,
                 "derived": f"speedup_vs_J0={t0 / t2:.2f}x"})
    # J3: both
    t3, c3 = _run(q, rels, caps, 8, repeats)
    assert c3 == c0
    rows.append({"name": "joinperf.J3_combined", "us": t3 * 1e6,
                 "derived": f"speedup_vs_J0={t0 / t3:.2f}x"})
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
