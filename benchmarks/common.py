"""Benchmark harness utilities: best-of-N timing, CSV emission."""
from __future__ import annotations

import time


def timeit(fn, repeats: int = 3, warmup: int = 1):
    """Best-of-N wall time in seconds; returns (best_s, result)."""
    result = None
    for _ in range(warmup):
        result = fn()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        result = fn()
        best = min(best, time.perf_counter() - t0)
    return best, result


def emit(rows: list[dict], path: str | None = None):
    """Print `name,us_per_call,derived` CSV; optionally write to path."""
    lines = ["name,us_per_call,derived"]
    for r in rows:
        lines.append(f"{r['name']},{r['us']:.1f},{r.get('derived', '')}")
    text = "\n".join(lines)
    print(text)
    if path:
        with open(path, "w") as f:
            f.write(text + "\n")
    return text
