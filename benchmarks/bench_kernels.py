"""Kernel microbenchmarks (not a paper table): the jnp path timed on CPU,
the Pallas path validated in interpret mode (TPU is the target; interpret
timing is not meaningful). Also times the compiled static-shape engine vs
the eager engine on the triangle query."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import timeit
from repro.kernels import ops


def run(repeats: int = 5):
    rows = []
    rng = np.random.default_rng(0)
    for n, q in ((10_000, 100_000), (100_000, 1_000_000)):
        keys = np.unique(rng.integers(0, 2**30, (n + n // 2, 2)).astype(np.int32), axis=0)[:n]
        table = ops.build_table(jnp.asarray(keys))
        queries = jnp.asarray(
            np.vstack(
                [
                    keys[rng.integers(0, n, q // 2)],
                    rng.integers(2**30, 2**31 - 1, (q // 2, 2)).astype(np.int32),
                ]
            )
        )
        probe = jax.jit(lambda t, qq: ops.probe(t, qq))
        probe(table, queries).block_until_ready()
        t, _ = timeit(lambda: probe(table, queries).block_until_ready(), repeats)
        rows.append(
            {
                "name": f"kern.hash_probe.n{n}.q{q}",
                "us": t * 1e6,
                "derived": f"{q / t / 1e6:.1f}Mprobe/s",
            }
        )
        tb, _ = timeit(lambda: jax.block_until_ready(ops.build_table(jnp.asarray(keys))), repeats)
        rows.append(
            {
                "name": f"kern.build_table.n{n}",
                "us": tb * 1e6,
                "derived": f"{n / tb / 1e6:.1f}Mkey/s",
            }
        )
    a = jnp.asarray(np.sort(rng.integers(0, 2**30, 100_000).astype(np.int32)))
    b = jnp.asarray(np.sort(np.unique(rng.integers(0, 2**30, 100_000).astype(np.int32))))
    isect = jax.jit(lambda x, y: ops.intersect_sorted(x, y))
    jax.block_until_ready(isect(a, b))
    t, _ = timeit(lambda: jax.block_until_ready(isect(a, b)), repeats)
    rows.append(
        {"name": "kern.intersect.100k", "us": t * 1e6, "derived": f"{len(a) / t / 1e6:.1f}Mkey/s"}
    )

    # compiled static engine vs eager engine (triangle count)
    from repro.core import binary2fj, factor, free_join
    from repro.core.compiled import count_query
    from repro.relational.relation import Relation
    from repro.relational.schema import triangle_query

    qy = triangle_query()
    rels = {
        a_.alias: Relation(a_.alias, {v: rng.integers(0, 300, 20_000) for v in a_.vars})
        for a_ in qy.atoms
    }
    fj = factor(binary2fj(qy.atoms, qy))
    te, ce = timeit(lambda: free_join(qy, rels, agg="count"), repeats)
    caps = [1 << 22] * 4
    tc, (cc, ovf) = timeit(lambda: count_query(fj, rels, caps), 2)
    assert ce == cc and not ovf, (ce, cc)
    rows.append(
        {
            "name": "kern.triangle20k.eager_vs_compiled",
            "us": te * 1e6,
            "derived": f"compiled_us={tc * 1e6:.0f};count={ce}",
        }
    )
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
