"""CI perf gate: diff a bench-smoke latest.csv against the smoke baseline
recorded in BENCH_join_perf.json and fail on a >2x regression of any
recorded row.

  PYTHONPATH=src python -m benchmarks.check_regression \
      benchmarks/results/latest.csv BENCH_join_perf.json
  PYTHONPATH=src python -m benchmarks.check_regression ... --update

--update re-records the baseline from the given CSV (run it after an
intentional perf change, alongside regenerating the full-scale record).
Only rows present in the baseline are checked, so new benchmarks don't
fail the gate until a baseline is recorded for them. The factor (default
2x, override BENCH_REGRESSION_FACTOR) is deliberately loose: CI runners
are noisy and slower than dev machines — the gate exists to catch
order-of-magnitude slips (an accidentally disabled cache, a rebuild
sneaking back into the warm path), not single-digit drift.

Rows whose name ends in `_qps` carry a throughput (higher is better) in
the value column instead of a latency; the gate inverts the ratio for
them, failing when throughput drops below baseline/factor.

When $GITHUB_STEP_SUMMARY is set (any GitHub Actions job), the per-row
comparison is also rendered there as a markdown table, so a failing gate
shows which rows moved without digging through the log.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def read_csv(path: str) -> dict[str, float]:
    rows: dict[str, float] = {}
    with open(path) as f:
        header = f.readline()
        assert header.startswith("name,"), f"unexpected CSV header: {header!r}"
        for line in f:
            parts = line.rstrip("\n").split(",", 2)
            if len(parts) >= 2 and parts[0]:
                rows[parts[0]] = float(parts[1])
    return rows


def _write_step_summary(table, factor: float, failed: list[str]) -> None:
    """Render the per-row comparison as markdown into $GITHUB_STEP_SUMMARY
    (no-op outside Actions). `_qps` rows show throughput values; every
    ratio is normalized so >1 means worse."""
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path or not table:
        return
    verdict = f"FAILED ({len(failed)} row(s) past {factor:.1f}x)" if failed else "passed"
    lines = [
        f"### Perf regression gate: {verdict}",
        "",
        "| row | baseline | measured | ratio |",
        "|---|---:|---:|---:|",
    ]
    for name, base, got, ratio, status in table:
        unit = "qps" if name.endswith("_qps") else "µs"
        mark = " ⚠️" if status in ("FAIL", "missing") else ""
        if got is None:
            lines.append(f"| `{name}` | {base:.1f} {unit} | missing | — {mark}|")
        else:
            lines.append(
                f"| `{name}` | {base:.1f} {unit} | {got:.1f} {unit} | {ratio:.2f}x{mark} |"
            )
    lines.append("")
    with open(path, "a") as f:
        f.write("\n".join(lines) + "\n")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("csv", help="bench-smoke latest.csv")
    ap.add_argument("record", help="BENCH_join_perf.json with a smoke_baseline section")
    ap.add_argument(
        "--update", action="store_true", help="re-record the baseline from the CSV"
    )
    ap.add_argument(
        "--prefix", default="joinperf.", help="only gate rows with this name prefix"
    )
    args = ap.parse_args()
    rows = read_csv(args.csv)
    with open(args.record) as f:
        record = json.load(f)
    if args.update:
        record["smoke_baseline"] = {
            k: round(v, 1) for k, v in sorted(rows.items()) if k.startswith(args.prefix)
        }
        with open(args.record, "w") as f:
            json.dump(record, f, indent=2)
            f.write("\n")
        print(f"recorded {len(record['smoke_baseline'])} baseline rows")
        return 0
    baseline = record.get("smoke_baseline", {})
    if not baseline:
        print("no smoke_baseline recorded; nothing to gate")
        return 0
    factor = float(os.environ.get("BENCH_REGRESSION_FACTOR", "2.0"))
    failed, table = [], []
    for name, base_us in sorted(baseline.items()):
        got = rows.get(name)
        if got is None:
            failed.append(f"{name}: missing from {args.csv} (baseline {base_us:.0f}us)")
            table.append((name, base_us, None, None, "missing"))
            continue
        if name.endswith("_qps"):  # throughput row: regression = DROP
            ratio = base_us / got if got else float("inf")
            unit = "qps"
        else:
            ratio = got / base_us
            unit = "us"
        status = "FAIL" if ratio > factor else "ok"
        print(f"{status:>4}  {name:<42} {got:>12.0f}{unit}  baseline {base_us:>10.0f}{unit}  {ratio:5.2f}x")
        table.append((name, base_us, got, ratio, status))
        if ratio > factor:
            failed.append(
                f"{name}: {got:.0f}{unit} regressed more than {factor:.1f}x "
                f"from baseline {base_us:.0f}{unit}"
            )
    _write_step_summary(table, factor, failed)
    if failed:
        print(f"\n{len(failed)} row(s) regressed more than {factor:.1f}x:", file=sys.stderr)
        for f_ in failed:
            print("  " + f_, file=sys.stderr)
        return 1
    print(f"\nall {len(baseline)} recorded rows within {factor:.1f}x")
    return 0


if __name__ == "__main__":
    sys.exit(main())
