"""Fig. 15/20 reproduction: robustness to bad plans.

The optimizer's cardinality estimator is pinned to 1 (the paper's hijack),
which degenerates join ordering to input order and emits bushy trees that
materialize large intermediates. We compare each algorithm's slowdown
bad/good. Paper: relative order FJ < BJ (fastest) persists; FJ and BJ both
slow down substantially, GJ least (it was slowest to begin with)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import timeit
from benchmarks.datagen import job_queries, job_tables
from repro.core import binary_join, free_join, generic_join, optimize


def run(scale: float = 0.05, repeats: int = 1):
    rows = []
    tables = job_tables(scale)
    slowdowns = {"fj": [], "bj": [], "gj": []}
    for name, q, rels in job_queries(tables):
        if name == "q_clover_adv":
            continue  # bad-plan binary join on the adversarial instance is unbounded
        good = optimize(q, rels, bad=False)
        bad = optimize(q, rels, bad=True)
        res = {}
        for lbl, fn in (
            ("fj", lambda p: free_join(q, rels, p, agg="count")),
            ("bj", lambda p: binary_join(q, rels, p, agg="count")),
            ("gj", lambda p: generic_join(q, rels, plan_tree=p, agg="count")),
        ):
            tg, cg = timeit(lambda f=fn: f(good), repeats, warmup=0)
            tb, cb = timeit(lambda f=fn: f(bad), repeats, warmup=0)
            assert cg == cb, (name, lbl)
            res[lbl] = (tg, tb)
            slowdowns[lbl].append(tb / tg)
        rows.append(
            {
                "name": f"robust.{name}",
                "us": res["fj"][0] * 1e6,
                "derived": ";".join(
                    f"{lbl}_bad/good={tb / tg:.2f}x" for lbl, (tg, tb) in res.items()
                )
                + ";fastest_bad="
                + (
                    "fj"
                    if res["fj"][1] <= min(res["bj"][1], res["gj"][1])
                    else ("bj" if res["bj"][1] < res["gj"][1] else "gj")
                ),
            }
        )
    gm = lambda v: float(np.exp(np.mean(np.log(v))))  # noqa: E731
    rows.append(
        {
            "name": "robust.geomean_slowdown",
            "us": 0.0,
            "derived": ";".join(f"{lbl}={gm(v):.2f}x" for lbl, v in slowdowns.items()),
        }
    )
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
