"""Synthetic datasets mirroring the paper's benchmarks.

JOB (Join Order Benchmark) ships the IMDB dump and LSQB generates a social
network — neither is available offline, so we generate data with the same
*shape characteristics* the paper's analysis hinges on:
  * JOB-like: a star schema around `title` with several many-to-many
    satellite tables whose foreign keys are Zipf-skewed (the paper's Q13a
    bottleneck: 3 m2m joins on one attribute exploding to 1e8 rows under a
    binary plan — our q_star3 reproduces that clover pattern).
  * LSQB-like: person-knows-person graph with Zipf degrees + attribute
    tables; q1-q5 mirror LSQB's mix (cyclic triangle / cyclic with
    attributes / 4-cycle / star / path).
Queries are full CQs (selections prepushed, aggregation = COUNT or full
materialization outside the timer, as in Sec 5.1).
"""
from __future__ import annotations

import numpy as np

from repro.relational.relation import Relation
from repro.relational.schema import Atom, Query


def _zipf(rng, n, domain, a=1.3):
    """Zipf-skewed foreign keys with an independent permutation of the
    domain per call: each table has heavy hitters, but *different* ones
    (shared heavy keys with a small final output is covered separately by
    q_clover_adv, the paper's Fig. 3 instance)."""
    z = rng.zipf(a, n)
    perm = rng.permutation(domain)
    return perm[(z - 1) % domain].astype(np.int64)


# ---------------------------------------------------------------------------
# JOB-like
# ---------------------------------------------------------------------------


def job_tables(scale: float = 1.0, seed: int = 0) -> dict[str, Relation]:
    rng = np.random.default_rng(seed)
    n_title = int(50_000 * scale)
    n_m2m = int(120_000 * scale)
    n_person = int(30_000 * scale)
    n_company = max(50, int(2_000 * scale))
    n_keyword = max(100, int(5_000 * scale))

    title = Relation(
        "title",
        {
            "t": np.arange(n_title, dtype=np.int64),
            "kind": rng.integers(0, 7, n_title),
            "year": rng.integers(1950, 2020, n_title),
        },
    )
    cast_info = Relation(
        "cast_info",
        {
            "t": _zipf(rng, n_m2m, n_title),
            "p": _zipf(rng, n_m2m, n_person),
            "role": rng.integers(0, 11, n_m2m),
        },
    )
    movie_companies = Relation(
        "movie_companies",
        {
            "t": _zipf(rng, n_m2m // 2, n_title),
            "c": _zipf(rng, n_m2m // 2, n_company),
        },
    )
    movie_keyword = Relation(
        "movie_keyword",
        {
            "t": _zipf(rng, n_m2m, n_title),
            "k": _zipf(rng, n_m2m, n_keyword),
        },
    )
    movie_info = Relation(
        "movie_info",
        {
            "t": _zipf(rng, n_m2m // 2, n_title),
            "info": rng.integers(0, 110, n_m2m // 2),
        },
    )
    person = Relation(
        "person",
        {"p": np.arange(n_person, dtype=np.int64), "gender": rng.integers(0, 3, n_person)},
    )
    company = Relation(
        "company",
        {"c": np.arange(n_company, dtype=np.int64), "country": rng.integers(0, 50, n_company)},
    )
    keyword = Relation(
        "keyword",
        {"k": np.arange(n_keyword, dtype=np.int64), "kw_type": rng.integers(0, 5, n_keyword)},
    )
    return {
        "title": title,
        "cast_info": cast_info,
        "movie_companies": movie_companies,
        "movie_keyword": movie_keyword,
        "movie_info": movie_info,
        "person": person,
        "company": company,
        "keyword": keyword,
    }


def _sel(rel: Relation, col: str, pred) -> Relation:
    return rel.select(pred(np.asarray(rel.columns[col])))


def job_queries(tables: dict[str, Relation]):
    """(name, Query, relations) triples. Selections are pre-pushed."""
    t, ci, mc, mk, mi = (
        tables["title"],
        tables["cast_info"],
        tables["movie_companies"],
        tables["movie_keyword"],
        tables["movie_info"],
    )
    person, company, keyword = tables["person"], tables["company"], tables["keyword"]
    out = []

    # q_chain4: title -> cast_info -> person (chain with filters)
    q = Query(
        [
            Atom("title", ("t", "kind")),
            Atom("cast_info", ("t", "p", "role")),
            Atom("person", ("p", "gender")),
        ]
    )
    rels = {
        "title": _sel(t, "year", lambda y: y >= 2000).rename({}, "title"),
        "cast_info": ci,
        "person": person,
    }
    rels["title"] = Relation(
        "title", {"t": rels["title"].columns["t"], "kind": rels["title"].columns["kind"]}
    )
    out.append(("q_chain3", q, rels))

    # q_star4_m2m (Q13a-like): 3 many-to-many joins on t + a selective
    # satellite that prunes. Under skew-blind estimates a binary plan can
    # order the m2m joins first and explode; Free Join factors the probes
    # into the first node (clover form) and never expands the m2m product.
    q = Query(
        [
            Atom("cast_info", ("t", "p")),
            Atom("movie_keyword", ("t", "k")),
            Atom("movie_companies", ("t", "c")),
            Atom("movie_info", ("t", "info")),
        ]
    )
    rels = {
        "cast_info": Relation("cast_info", {"t": ci.columns["t"], "p": ci.columns["p"]}),
        "movie_keyword": mk,
        "movie_companies": mc,
        "movie_info": _sel(mi, "info", lambda i: i == 3),
    }
    out.append(("q_star4_m2m", q, rels))

    # q_star4: star with a selective filter on one satellite
    q = Query(
        [
            Atom("title", ("t", "year")),
            Atom("movie_info", ("t", "info")),
            Atom("movie_keyword", ("t", "k")),
            Atom("keyword", ("k", "kw_type")),
        ]
    )
    rels = {
        "title": Relation("title", {"t": t.columns["t"], "year": t.columns["year"]}),
        "movie_info": _sel(mi, "info", lambda i: i == 3),
        "movie_keyword": mk,
        "keyword": _sel(keyword, "kw_type", lambda i: i == 2),
    }
    out.append(("q_star4_sel", q, rels))

    # q_chain5: company -> movie_companies -> title -> cast_info -> person
    q = Query(
        [
            Atom("company", ("c", "country")),
            Atom("movie_companies", ("t", "c")),
            Atom("title", ("t", "kind")),
            Atom("cast_info", ("t", "p")),
            Atom("person", ("p", "gender")),
        ]
    )
    rels = {
        "company": _sel(company, "country", lambda x: x < 5),
        "movie_companies": mc,
        "title": Relation("title", {"t": t.columns["t"], "kind": t.columns["kind"]}),
        "cast_info": Relation("cast_info", {"t": ci.columns["t"], "p": ci.columns["p"]}),
        "person": person,
    }
    out.append(("q_chain5", q, rels))

    # q_star5_wide: everything joined on t (wide clover)
    q = Query(
        [
            Atom("title", ("t", "kind")),
            Atom("cast_info", ("t", "p")),
            Atom("movie_keyword", ("t", "k")),
            Atom("movie_companies", ("t", "c")),
            Atom("movie_info", ("t", "info")),
        ]
    )
    rels = {
        "title": _sel(
            Relation("title", {"t": t.columns["t"], "kind": t.columns["kind"]}),
            "kind",
            lambda k: k == 1,
        ),
        "cast_info": Relation("cast_info", {"t": ci.columns["t"], "p": ci.columns["p"]}),
        "movie_keyword": mk,
        "movie_companies": mc,
        "movie_info": _sel(mi, "info", lambda i: i < 2),
    }
    out.append(("q_star5_wide", q, rels))

    # q_clover_adv: the paper's adversarial clover instance (Fig. 3/4),
    # n = 2000: every pairwise join has n^2 tuples but the full join has
    # exactly one. Any binary plan materializes n^2; Free Join runs O(n).
    n = 2000
    ar = np.arange(n, dtype=np.int64)
    R = Relation("R", {"x": np.concatenate([[0], np.full(n, 1), np.full(n, 2)]),
                       "va": np.concatenate([[0], ar, ar + n])})
    S = Relation("S", {"x": np.concatenate([[0], np.full(n, 2), np.full(n, 3)]),
                       "vb": np.concatenate([[0], ar, ar + n])})
    T = Relation("T", {"x": np.concatenate([[0], np.full(n, 3), np.full(n, 1)]),
                       "vc": np.concatenate([[0], ar, ar + n])})
    q = Query([Atom("R", ("x", "va")), Atom("S", ("x", "vb")), Atom("T", ("x", "vc"))])
    out.append(("q_clover_adv", q, {"R": R, "S": S, "T": T}))
    return out


# ---------------------------------------------------------------------------
# LSQB-like
# ---------------------------------------------------------------------------


def lsqb_tables(sf: float = 0.1, seed: int = 1) -> dict[str, Relation]:
    rng = np.random.default_rng(seed)
    n_person = int(30_000 * sf) + 100
    n_knows = int(180_000 * sf) + 200
    n_tag = max(20, int(1_000 * sf))
    n_city = max(10, int(500 * sf))
    src = _zipf(rng, n_knows, n_person, a=1.4)
    dst = _zipf(rng, n_knows, n_person, a=1.4)
    knows = Relation("knows", {"a": src, "b": dst})
    interest = Relation(
        "interest",
        {"a": _zipf(rng, 3 * n_person, n_person), "tag": _zipf(rng, 3 * n_person, n_tag)},
    )
    located = Relation(
        "located",
        {"a": np.arange(n_person, dtype=np.int64), "city": rng.integers(0, n_city, n_person)},
    )
    return {"knows": knows, "interest": interest, "located": located}


def lsqb_queries(tables: dict[str, Relation]):
    knows, interest, located = tables["knows"], tables["interest"], tables["located"]
    k_ab = knows
    out = []
    # q1: triangle (cyclic)
    q = Query(
        [
            Atom("knows", ("a", "b"), "K1"),
            Atom("knows", ("b", "c"), "K2"),
            Atom("knows", ("c", "a"), "K3"),
        ]
    )
    rels = {
        "K1": k_ab,
        "K2": k_ab.rename({"a": "b", "b": "c"}),
        "K3": k_ab.rename({"a": "c", "b": "a"}),
    }
    out.append(("q1_triangle", q, rels))
    # q2: triangle + interest (cyclic + attribute)
    q = Query(
        [
            Atom("knows", ("a", "b"), "K1"),
            Atom("knows", ("b", "c"), "K2"),
            Atom("knows", ("c", "a"), "K3"),
            Atom("interest", ("a", "tag"), "I"),
        ]
    )
    rels = {
        "K1": k_ab,
        "K2": k_ab.rename({"a": "b", "b": "c"}),
        "K3": k_ab.rename({"a": "c", "b": "a"}),
        "I": interest,
    }
    out.append(("q2_triangle_tag", q, rels))
    # q3: 4-cycle (many cycles)
    q = Query(
        [
            Atom("knows", ("a", "b"), "K1"),
            Atom("knows", ("b", "c"), "K2"),
            Atom("knows", ("c", "d"), "K3"),
            Atom("knows", ("d", "a"), "K4"),
        ]
    )
    rels = {
        "K1": k_ab,
        "K2": k_ab.rename({"a": "b", "b": "c"}),
        "K3": k_ab.rename({"a": "c", "b": "d"}),
        "K4": k_ab.rename({"a": "d", "b": "a"}),
    }
    out.append(("q3_square", q, rels))
    # q4: star (acyclic)
    q = Query(
        [
            Atom("knows", ("a", "b"), "K1"),
            Atom("interest", ("a", "tag"), "I"),
            Atom("located", ("a", "city"), "L"),
        ]
    )
    rels = {"K1": k_ab, "I": interest, "L": located}
    out.append(("q4_star", q, rels))
    # q5: path of length 3 (acyclic)
    q = Query(
        [
            Atom("knows", ("a", "b"), "K1"),
            Atom("knows", ("b", "c"), "K2"),
            Atom("located", ("c", "city"), "L"),
        ]
    )
    rels = {"K1": k_ab, "K2": k_ab.rename({"a": "b", "b": "c"}), "L": located.rename({"a": "c"})}
    out.append(("q5_path", q, rels))
    return out
