"""Fig. 14 reproduction: Free Join vs Generic Join vs binary join on
JOB-like acyclic queries. Reports per-query times and the geometric-mean
speedups the paper headlines (FJ 2.94x over BJ, 9.61x over GJ on JOB)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import timeit
from benchmarks.datagen import job_queries, job_tables
from repro.core import binary_join, free_join, generic_join, optimize


def run(scale: float = 0.1, repeats: int = 2):
    tables = job_tables(scale)
    rows = []
    speed_bj, speed_gj = [], []
    for name, q, rels in job_queries(tables):
        tree = optimize(q, rels)
        t_fj, out_fj = timeit(lambda: free_join(q, rels, tree, agg="count"), repeats, warmup=0)
        t_bj, out_bj = timeit(lambda: binary_join(q, rels, tree, agg="count"), repeats, warmup=0)
        t_gj, out_gj = timeit(
            lambda: generic_join(q, rels, plan_tree=tree, agg="count"), repeats, warmup=0
        )
        assert out_fj == out_bj == out_gj, (name, out_fj, out_bj, out_gj)
        speed_bj.append(t_bj / t_fj)
        speed_gj.append(t_gj / t_fj)
        rows.append(
            {
                "name": f"job.{name}.free_join",
                "us": t_fj * 1e6,
                "derived": f"|out|={out_fj};bj/fj={t_bj / t_fj:.2f}x;gj/fj={t_gj / t_fj:.2f}x",
            }
        )
        rows.append({"name": f"job.{name}.binary_join", "us": t_bj * 1e6, "derived": ""})
        rows.append({"name": f"job.{name}.generic_join", "us": t_gj * 1e6, "derived": ""})
    gm_bj = float(np.exp(np.mean(np.log(speed_bj))))
    gm_gj = float(np.exp(np.mean(np.log(speed_gj))))
    rows.append(
        {
            "name": "job.geomean_speedup",
            "us": 0.0,
            "derived": f"fj_over_bj={gm_bj:.2f}x;fj_over_gj={gm_gj:.2f}x"
            f";max_bj={max(speed_bj):.2f}x;max_gj={max(speed_gj):.2f}x",
        }
    )
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
