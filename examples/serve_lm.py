"""Serve a small model with batched requests: continuous batching, paged
KV bookkeeping, mixed prompt lengths.

  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.models.transformer import ModelConfig, init_params
from repro.serve import DecodeServeEngine, Request


def main():
    cfg = ModelConfig(
        name="demo-serve",
        num_layers=4,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        d_ff=1024,
        vocab=512,
        compute_dtype="float32",
        remat=False,
    )
    params = init_params(jax.random.PRNGKey(7), cfg)
    eng = DecodeServeEngine(params, cfg, slots=8, max_len=256)
    rng = np.random.default_rng(3)
    n_req = 24
    for i in range(n_req):
        prompt = rng.integers(0, cfg.vocab, int(rng.integers(4, 24))).astype(np.int32)
        eng.submit(Request(rid=i, prompt=prompt, max_new=32))
    t0 = time.time()
    eng.run()
    dt = time.time() - t0
    tok = n_req * 32
    print(f"served {n_req} requests / {tok} new tokens in {eng.steps} batched decode steps")
    print(f"{dt:.1f}s on CPU -> {tok / dt:.1f} tok/s; free KV pages: {len(eng.pages.free)}")


if __name__ == "__main__":
    main()
