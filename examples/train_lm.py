"""End-to-end driver: train a ~small LM for a few hundred steps on CPU,
with checkpoint/resume and a demonstrably decreasing loss (Markov data).

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.models.transformer import ModelConfig, init_params
from repro.train import AdamWConfig, TrainConfig, checkpoint, make_train_step
from repro.train.data import DataConfig, markov_batch
from repro.train.optimizer import init_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--resume-demo", action="store_true", help="kill + resume mid-run")
    args = ap.parse_args()

    # ~7M params: a few hundred steps finish in minutes on one CPU core;
    # scale num_layers/d_model up freely on real hardware.
    cfg = ModelConfig(
        name="demo-7m",
        num_layers=3,
        d_model=192,
        num_heads=6,
        num_kv_heads=3,
        d_ff=768,
        vocab=512,
        compute_dtype="float32",
        remat=False,
    )
    tcfg = TrainConfig(adamw=AdamWConfig(lr=1e-3, warmup_steps=30, total_steps=args.steps))
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = init_state(tcfg.adamw, params)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n / 1e6:.1f}M params")
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=96, global_batch=8)

    ckpt_dir = tempfile.mkdtemp(prefix="repro-ckpt-")
    first_loss = last_loss = None
    for step in range(args.steps):
        batch = jax.tree.map(jnp.asarray, markov_batch(dcfg, step))
        params, opt, m = step_fn(params, opt, batch)
        if first_loss is None:
            first_loss = float(m["loss"])
        last_loss = float(m["loss"])
        if (step + 1) % 50 == 0:
            print(f"step {step + 1:4d}  loss {last_loss:.4f}  lr {float(m['lr']):.2e}")
            checkpoint.save(ckpt_dir, step + 1, {"params": params, "opt": opt})
        if args.resume_demo and step == args.steps // 2:
            print("-- simulating failure: restoring from latest checkpoint --")
            latest = checkpoint.latest_step(ckpt_dir)
            if latest:
                state = checkpoint.restore(ckpt_dir, latest, {"params": params, "opt": opt})
                params, opt = state["params"], state["opt"]
    print(f"\nloss: {first_loss:.3f} -> {last_loss:.3f} "
          f"({'LEARNED' if last_loss < first_loss - 0.5 else 'check hyperparams'})")


if __name__ == "__main__":
    main()
