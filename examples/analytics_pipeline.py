"""The two pillars together: the Free Join engine running the *framework's*
relational work — corpus sample selection for LM training (DESIGN.md §5.1)
and distributed (HyperCube) counting of a graph statistic.

  PYTHONPATH=src python examples/analytics_pipeline.py
"""
import numpy as np

from repro.core.distributed import distributed_join_host, hypercube_shares
from repro.relational.relation import Relation
from repro.relational.schema import Atom, Query
from repro.train.data import DataConfig, select_corpus_samples, synthetic_batch


def main():
    rng = np.random.default_rng(0)
    n_docs = 200_000
    docs = Relation(
        "Docs",
        {
            "doc": np.arange(n_docs, dtype=np.int64),
            "shard": rng.integers(0, 64, n_docs),
            "lang": rng.integers(0, 30, n_docs),
        },
    )
    quality = Relation(
        "Quality",
        {"doc": np.arange(n_docs, dtype=np.int64), "score": rng.integers(0, 100, n_docs)},
    )
    canonical = np.arange(n_docs, dtype=np.int64)
    dup = rng.random(n_docs) < 0.2  # 20% duplicates point elsewhere
    canonical[dup] = rng.integers(0, n_docs, int(dup.sum()))
    dedup = Relation("Dedup", {"doc": np.arange(n_docs, dtype=np.int64), "canonical": canonical})

    keep = select_corpus_samples(docs, quality, dedup, min_quality=60)
    print(f"corpus selection: kept {len(keep):,} / {n_docs:,} docs "
          f"(quality>=60 and canonical) via Free Join")

    # feed the kept set into the deterministic batch stream
    dcfg = DataConfig(vocab=32000, seq_len=64, global_batch=8)
    batch = synthetic_batch(dcfg, step=0)
    print(f"first batch: inputs {batch['inputs'].shape}, labels {batch['labels'].shape}")

    # distributed analytics: triangle count over a follow graph, HyperCube
    n_edges, n_people = 60_000, 8_000
    knows = Relation(
        "knows",
        {"a": rng.integers(0, n_people, n_edges), "b": rng.integers(0, n_people, n_edges)},
    )
    q = Query(
        [
            Atom("knows", ("a", "b"), "K1"),
            Atom("knows", ("b", "c"), "K2"),
            Atom("knows", ("c", "a"), "K3"),
        ]
    )
    rels = {
        "K1": knows,
        "K2": knows.rename({"a": "b", "b": "c"}),
        "K3": knows.rename({"a": "c", "b": "a"}),
    }
    shares = hypercube_shares(q, {k: n_edges for k in rels}, 8)
    count = distributed_join_host(q, rels, num_shards=8, agg="count")
    print(f"triangle count over 8 HyperCube shards (shares={shares}): {count:,}")


if __name__ == "__main__":
    main()
