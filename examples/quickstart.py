"""Quickstart: Free Join on the paper's own examples.

Shows the whole pipeline: query -> cost-based binary plan -> binary2fj ->
factor -> COLT + vectorized execution, against the Generic Join and binary
join baselines, on the triangle query (Example 2.1) and the adversarial
clover instance (Fig. 3/4) — then the compiled static-shape path, where
frontier capacities come from the capacity planner (no manual sizes) and
overflow is recovered adaptively.

  PYTHONPATH=src python examples/quickstart.py
"""
import time

import numpy as np

from repro.core import (
    binary2fj,
    binary_join,
    compiled_free_join,
    factor,
    free_join,
    generic_join,
    optimize,
    to_sorted_tuples,
)
from repro.relational.relation import Relation
from repro.relational.schema import clover_query, triangle_query


def main():
    rng = np.random.default_rng(0)
    q = triangle_query()
    rels = {
        a.alias: Relation(a.alias, {v: rng.integers(0, 100, 5000) for v in a.vars})
        for a in q.atoms
    }
    tree = optimize(q, rels)
    fj_plan = binary2fj(q.atoms, q)
    print("query          :", q)
    print("binary2fj      :", fj_plan)
    print("factored       :", factor(fj_plan))
    for name, fn in (
        ("free join  ", lambda: free_join(q, rels, tree, agg="count")),
        ("binary join", lambda: binary_join(q, rels, tree, agg="count")),
        ("generic join", lambda: generic_join(q, rels, plan_tree=tree, agg="count")),
    ):
        t0 = time.perf_counter()
        c = fn()
        print(f"{name}: count={c}  ({(time.perf_counter() - t0) * 1e3:.1f} ms)")

    # the paper's adversarial clover instance: n^2 pairwise joins, 1 result
    n = 5000
    ar = np.arange(n, dtype=np.int64)
    qc = clover_query()
    rels = {
        "R": Relation(
            "R", {"x": np.r_[0, np.full(n, 1), np.full(n, 2)], "a": np.r_[0, ar, ar + n]}
        ),
        "S": Relation(
            "S", {"x": np.r_[0, np.full(n, 2), np.full(n, 3)], "b": np.r_[0, ar, ar + n]}
        ),
        "T": Relation(
            "T", {"x": np.r_[0, np.full(n, 3), np.full(n, 1)], "c": np.r_[0, ar, ar + n]}
        ),
    }
    tree = optimize(qc, rels)
    print("\nclover (adversarial skew, n =", n, ")")
    for name, fn in (
        ("free join  ", lambda: free_join(qc, rels, tree)),
        ("binary join", lambda: binary_join(qc, rels, tree)),
    ):
        t0 = time.perf_counter()
        bound, mult = fn()
        rows = to_sorted_tuples((bound, mult), qc.head)
        print(f"{name}: output={rows}  ({(time.perf_counter() - t0) * 1e3:.1f} ms)")

    # the compiled path: same triangle count, static shapes, jit. The
    # capacity planner sizes every frontier buffer from the optimizer's
    # estimates capped by the AGM bound — no manual capacities — and the
    # adaptive runner grows any buffer that still overflows and retries.
    rng = np.random.default_rng(0)
    q = triangle_query()
    rels = {
        a.alias: Relation(a.alias, {v: rng.integers(0, 100, 5000) for v in a.vars})
        for a in q.atoms
    }
    print("\ncompiled path (static shapes, planner-derived capacities)")
    info = {}
    t0 = time.perf_counter()
    c = compiled_free_join(q, rels, agg="count", info=info)
    t1 = time.perf_counter()
    print(f"cold        : count={c}  ({(t1 - t0) * 1e3:.1f} ms incl. build + compile)")
    # steady state — build once, probe many: the cold call uploaded the
    # columns, built every trie (segmented radix sort + lazy hash tables),
    # compiled the probe program, and cached all three process-wide. A
    # repeated identical call is pure probe work: zero np.unique, zero trie
    # builds, zero recompiles — the serving loop below converges to the
    # warm floor after the first iteration.
    for i in range(3):
        t2 = time.perf_counter()
        c2 = compiled_free_join(q, rels, agg="count", info=info)
        t3 = time.perf_counter()
        print(f"warm call {i} : count={c2}  ({(t3 - t2) * 1e3:.1f} ms, probe only)")
        assert c2 == c
    print(f"plan        : {info['cap_plan']}  retries={info['retries']}")
    assert c == free_join(q, rels, agg="count")

    # bushy plans, fully compiled: a binary plan tree with a join on its
    # right side decomposes into stages (Sec 2.2). The compiled path runs
    # the WHOLE chain as one on-device program — each non-root stage's
    # output stays on the device as a padded, multiplicity-weighted buffer
    # that the next stage builds its trie from; the eager engine is never
    # invoked. Per-stage capacities come from estimated stage statistics
    # and any stage's overflow grows exactly the offending buffer.
    from repro.core.plan import BinaryPlan
    from repro.relational.schema import Atom, Query

    qb = Query(
        [Atom("A", ("x", "y")), Atom("B", ("y", "z")), Atom("C", ("z", "w")), Atom("D", ("w", "u"))]
    )
    relsb = {
        a.alias: Relation(a.alias, {v: rng.integers(0, 500, 1500) for v in a.vars})
        for a in qb.atoms
    }
    # (A ⋈ B) ⋈ (C ⋈ D): the right subtree becomes a materialized stage
    bushy = BinaryPlan(
        BinaryPlan(qb.atoms[0], qb.atoms[1]), BinaryPlan(qb.atoms[2], qb.atoms[3])
    )
    print("\nbushy plan, fully compiled (stage chained on device)")
    info = {}
    t0 = time.perf_counter()
    cb = compiled_free_join(qb, relsb, bushy, agg="count", info=info)
    t1 = time.perf_counter()
    print(f"chained     : count={cb}  ({(t1 - t0) * 1e3:.1f} ms incl. compile)")
    print(f"chain plan  : {info['cap_plan']}")
    assert cb == free_join(qb, relsb, bushy, agg="count")

    # cost-based plan enumeration: no hand-written tree this time. The
    # ExecOptions.optimize_level knob picks the plan-choice effort — 0 is
    # the greedy left-deep search, 1 (default) enumerates bushy candidates
    # by dynamic programming over connected subqueries and ranks them with
    # a device cost model (frontier cells touched, AGM-capped), 2 makes the
    # enumeration exhaustive and re-plans when measured cardinalities from
    # earlier runs contradict the estimates. On this chain the middle join
    # (b ⋈ c over a small domain) is dense while both end joins are
    # selective: greedy must drag the dense intermediate left-deep, the
    # enumeration brackets it bushy.
    from repro.core import ExecOptions

    relsd = {
        "A": Relation("A", {"x": rng.integers(0, 1500, 1500), "y": rng.integers(0, 1500, 1500)}),
        "B": Relation("B", {"y": rng.integers(0, 1500, 1500), "z": rng.integers(0, 12, 1500)}),
        "C": Relation("C", {"z": rng.integers(0, 12, 1500), "w": rng.integers(0, 1500, 1500)}),
        "D": Relation("D", {"w": rng.integers(0, 1500, 1500), "u": rng.integers(0, 1500, 1500)}),
    }
    print("\ncost-based plan enumeration (ExecOptions.optimize_level)")
    for level in (0, 2):
        info = {}
        c = compiled_free_join(
            qb, relsd, agg="count", options=ExecOptions(optimize_level=level), info=info
        )
        print(f"level {level}     : count={c}  plan={info['plan_tree']}")

    # static verification: ExecOptions(verify=True) runs the plan/schedule/
    # capacity linter (repro.analysis) over the freshly planned chain before
    # anything compiles — structural defects (unbound probe vars, missing
    # covers, capacities past the AGM cap, broken stage wiring) surface as
    # typed diagnostics with plan-path locations instead of shape errors
    # deep inside jit. The lint runs once per build, never on warm hits.
    c = compiled_free_join(qb, relsd, agg="count", options=ExecOptions(verify=True))
    print(f"verified    : count={c}  (ExecOptions(verify=True) linted the plan pre-compile)")

    # multi-tenant serving loop: concurrent tenants send the SAME query in
    # different spellings (their own aliases) with their own selection
    # constants. JoinServeEngine canonicalizes each request into a plan
    # template — alias alpha-renaming + constant lifting — so all of them
    # share ONE compiled executor, and co-template requests are answered by
    # ONE vmapped dispatch over the shared cached tries (the constants
    # matrix is the only per-lane input). Admission quotas (see
    # src/repro/serve/README.md) reject oversized queries instead of
    # letting them stall the batch with a grow/recompile storm.
    from repro.serve import JoinServeEngine

    print("\nserving loop (plan templates + batched probes)")
    eng = JoinServeEngine(slots=4)
    reqs = []
    for i, c in enumerate((3, 17, 41, 88)):
        # tenant i's spelling: same triangle, different alias names
        qi = Query([Atom(a.name, a.vars, f"tenant{i}_{a.alias}") for a in q.atoms])
        ri = {f"tenant{i}_{a.alias}": rels[a.alias] for a in q.atoms}
        reqs.append(eng.submit(qi, ri, {"x": c}, tenant=f"tenant{i}"))
    assert len({r.template.key for r in reqs}) == 1  # one template for all
    t0 = time.perf_counter()
    eng.run()
    t1 = time.perf_counter()
    for r, c in zip(reqs, (3, 17, 41, 88)):
        assert r.result == free_join(q, rels, agg="count", filters={"x": c})
        print(f"  x={c:>2}: count={r.result}")
    print(f"4 tenants, {eng.dispatches} batched dispatch ({(t1 - t0) * 1e3:.1f} ms incl. compile)")

    # resilience: a fault the quota machinery has no protocol for — here an
    # injected XLA compile failure, in production a device OOM or a
    # memory-governor shed — never crashes step(). The group descends a
    # degradation ladder (full-width batch -> halved batch -> unbatched ->
    # eager host engine) and every admitted request still answers
    # correctly, with the rung recorded on the handle as `degraded_to`.
    from repro.core import faults

    print("\nresilience (degradation ladder under an injected compile failure)")
    reng = JoinServeEngine(slots=2)
    with faults.inject("compile_fail", times=1) as f:
        r0 = reng.submit(q, rels, {"x": 3}, tenant="tenantA")
        r1 = reng.submit(q, rels, {"x": 17}, tenant="tenantB")
        reng.run()
    for r, c in zip((r0, r1), (3, 17)):
        assert r.done and r.error is None
        assert r.result == free_join(q, rels, agg="count", filters={"x": c})
    print(f"  compile faults injected: {f.fired}; absorbed: {reng.faults_absorbed}")
    print(f"  x= 3: count={r0.result}  (degraded_to={r0.degraded_to})")
    print(f"  x=17: count={r1.result}  (degraded_to={r1.degraded_to})")
    print("  both answers correct — the query survived the failed compile")

    # streaming ingest + standing queries: relations mutate through the
    # relcache delta API (append/delete), and the cached trie absorbs each
    # batch with ONE delta merge — the batch is sorted alone and spliced
    # into the cached level buffers, never a full re-sort; deletes
    # tombstone rows at multiplicity 0 until a compaction threshold. A
    # StandingQueryEngine keeps registered queries answered across
    # ingests, recomputing only the plan stages whose input fingerprints
    # moved — unchanged stages replay their cached device buffers.
    from repro.core import relcache
    from repro.serve import StandingQueryEngine

    print("\nstreaming ingest (delta tries + standing query)")
    seng = StandingQueryEngine()
    sq = seng.register(q, rels, agg="count")
    print(f"  registered : count={sq.result}")
    for step in range(3):
        delta = {
            "x": rng.integers(0, 200, 256),
            "y": rng.integers(0, 200, 256),
        }
        t0 = time.perf_counter()
        seng.ingest(rels["R"], delta)  # append + refresh every standing query
        t1 = time.perf_counter()
        assert sq.result == free_join(q, rels, agg="count")
        print(f"  ingest {step}   : count={sq.result}  ({(t1 - t0) * 1e3:.1f} ms)")
    relcache.delete(rels["R"], np.arange(64))  # tombstones, then refresh
    seng.refresh()
    assert sq.result == free_join(q, {**rels, "R": relcache.live_relation(rels["R"])}, agg="count")
    from repro.core.compiled import TRIE_CACHE

    print(f"  delete 64  : count={sq.result}  "
          f"({TRIE_CACHE.delta_merges} delta merges, {TRIE_CACHE.tombstone_refreshes} "
          f"tombstone refresh — zero full rebuilds after the cold build)")


if __name__ == "__main__":
    main()
